//! BanditPAM (Algorithm 2 applied to PAM): each BUILD assignment and each
//! SWAP search is a fixed-confidence best-arm identification problem run
//! on the shared engine, with per-arm σ̂ re-estimated every call (§2.3.2)
//! and the FastPAM1 distance-sharing optimization in the SWAP arms
//! (§A.1.1): one d(x, x_j) evaluation serves all k swap arms of x.
//!
//! Both arm sets implement the sharded observation API: BUILD shards by
//! candidate, SWAP shards by candidate *group* (the k arms of one x stay
//! on one shard so FastPAM1's shared distance evaluation is computed
//! exactly once — parallel distance-call totals equal the sequential
//! ones). Deltas are applied in fixed arm order, so `threads != 1`
//! returns bit-identical medoids, losses, and counter totals.
//!
//! Distance pulls are **batched** ([`crate::kernels`]): each arm (or
//! FastPAM1 arm group) evaluates a whole reference batch with one
//! [`PointSet::dist_batch`] call — the candidate's row is gathered once
//! per batch instead of once per pair, and view-backed point sets serve
//! the references with block-scheduled kernel reads. The per-arm folds
//! still run in batch order, so results and distance-call totals are
//! bit-identical to the scalar per-pull path.

use super::{KmConfig, KmResult, MedoidCache};
use crate::bandit::{successive_elimination, AdaptiveArms, ArmStats, BanditConfig, ParCtx, Sampling};
use crate::data::PointSet;
use crate::kernels::scratch;

/// Fold medoid `m`'s distance row into the d₁ cache: one batched
/// [`PointSet::dist_batch`] sweep over all points (the arm's row is
/// gathered once, chunked stores serve block-scheduled reads) — counted
/// exactly like the n scalar calls it replaces.
fn fold_d1<P: PointSet + ?Sized>(ps: &P, m: usize, d1: &mut [f64]) {
    let n = ps.len();
    let idx = scratch::iota(n);
    let mut dists = scratch::f64_buf(n);
    ps.dist_batch(m, &idx, &mut dists);
    for (slot, &d) in d1.iter_mut().zip(dists.iter()) {
        if d < *slot {
            *slot = d;
        }
    }
}

/// BanditPAM tuning knobs (paper defaults: B = 100, δ = 1/(1000·|S_tar|)).
#[derive(Clone, Debug)]
pub struct BanditPamConfig {
    pub km: KmConfig,
    pub batch_size: usize,
    /// δ numerator: δ = delta_scale / |S_tar|. Paper: 1/1000 ⇒ 0.001.
    pub delta_scale: f64,
    /// Shard-parallel observation (see [`BanditConfig::threads`]).
    pub threads: usize,
}

impl BanditPamConfig {
    pub fn new(k: usize) -> Self {
        BanditPamConfig { km: KmConfig::new(k), batch_size: 100, delta_scale: 1e-3, threads: 1 }
    }
}

/// Extended result: BanditPAM also reports per-BUILD-step σ̂ snapshots
/// (Fig. A.1) and the first BUILD step's exact arm means (Fig. A.2) when
/// requested via [`bandit_pam_instrumented`].
#[derive(Clone, Debug)]
pub struct BanditPamStats {
    /// For each BUILD step: the σ̂_x of all surviving-at-start arms.
    pub build_sigmas: Vec<Vec<f64>>,
}

/// Run BanditPAM.
pub fn bandit_pam<P: PointSet + ?Sized>(ps: &P, cfg: &BanditPamConfig) -> KmResult {
    bandit_pam_instrumented(ps, cfg).0
}

/// Run BanditPAM and return instrumentation alongside the result.
pub fn bandit_pam_instrumented<P: PointSet + ?Sized>(
    ps: &P,
    cfg: &BanditPamConfig,
) -> (KmResult, BanditPamStats) {
    let _span = crate::obs::span("solver.banditpam");
    let before = ps.counter().get();
    let n = ps.len();
    let k = cfg.km.k;
    assert!(k >= 1 && k <= n);
    let mut stats = BanditPamStats { build_sigmas: Vec::new() };

    // ---------------- BUILD ----------------
    let mut medoids: Vec<usize> = Vec::with_capacity(k);
    let mut d1 = vec![f64::INFINITY; n];
    {
        let _span = crate::obs::span("solver.banditpam.build");
        for step in 0..k {
            stats.build_sigmas.push(build_step(ps, cfg, &mut medoids, &mut d1, step));
        }
    }

    // ---------------- SWAP ----------------
    let swaps = {
        let _span = crate::obs::span("solver.banditpam.swap");
        swap_phase(ps, cfg, &mut medoids)
    };
    (finish(ps, medoids, swaps, before), stats)
}

/// Warm-started re-solve: adopt the previous solution's medoids as the
/// starting point, skipping BUILD entirely when all of them survive into
/// the current view (the ISSUE's "seed from the previous solution" — the
/// medoids *are* the previous per-arm state worth keeping; the SWAP
/// search re-verifies optimality against the changed data and only pays
/// for what actually moved). Medoids whose rows were deleted are
/// replaced by warm BUILD steps over the survivors' d₁ cache.
///
/// On stable data (appends that respect the cluster structure — the
/// refresh fixture corpus) this converges to the same medoids as a cold
/// [`bandit_pam`] on the same snapshot for a fraction of the distance
/// evaluations; the acceptance tests assert both on [`crate::metrics::OpCounter`]s.
pub fn bandit_pam_refresh<P: PointSet + ?Sized>(
    ps: &P,
    prev_medoids: &[usize],
    cfg: &BanditPamConfig,
) -> KmResult {
    let _span = crate::obs::span("solver.banditpam_refresh");
    let before = ps.counter().get();
    let n = ps.len();
    let k = cfg.km.k;
    assert!(k >= 1 && k <= n);

    // Adopt the surviving previous medoids (in-range, de-duplicated).
    let mut medoids: Vec<usize> = Vec::with_capacity(k);
    for &m in prev_medoids {
        if m < n && !medoids.contains(&m) && medoids.len() < k {
            medoids.push(m);
        }
    }

    // Replace lost medoids with warm BUILD steps (first = false: the d₁
    // cache of the survivors already shapes the objective).
    if medoids.len() < k {
        let mut d1 = vec![f64::INFINITY; n];
        for i in 0..medoids.len() {
            fold_d1(ps, medoids[i], &mut d1);
        }
        for step in medoids.len()..k {
            build_step(ps, cfg, &mut medoids, &mut d1, step);
        }
    }

    let swaps = swap_phase(ps, cfg, &mut medoids);
    finish(ps, medoids, swaps, before)
}

/// One BUILD step (Algorithm 2 over Eq. 2.5): pick the next medoid among
/// all non-medoids, push it, fold it into the d₁ cache. Returns the
/// per-candidate σ̂ snapshot (Fig. A.1 instrumentation).
fn build_step<P: PointSet + ?Sized>(
    ps: &P,
    cfg: &BanditPamConfig,
    medoids: &mut Vec<usize>,
    d1: &mut [f64],
    step: usize,
) -> Vec<f64> {
    let n = ps.len();
    let candidates: Vec<usize> = (0..n).filter(|x| !medoids.contains(x)).collect();
    let first = medoids.is_empty();
    let mut arms = BuildArms {
        ps,
        d1: &*d1,
        candidates: &candidates,
        first,
        stats: ArmStats::new(candidates.len()),
    };
    let bcfg = BanditConfig {
        delta: cfg.delta_scale / candidates.len() as f64,
        batch_size: cfg.batch_size,
        sampling: Sampling::Permutation,
        keep: 1,
        seed: cfg.km.seed ^ (0xB111D + step as u64),
        threads: cfg.threads,
    };
    let r = successive_elimination(&mut arms, &bcfg);
    let sigmas = (0..candidates.len()).map(|a| arms.sigma(a)).collect();
    let m = candidates[r.best[0]];
    medoids.push(m);
    fold_d1(ps, m, d1);
    sigmas
}

/// The SWAP loop shared by the cold and warm entry points: repeat
/// best-swap identification until no swap improves (PAM's convergence
/// criterion), mutating `medoids` in place. Returns the number of swaps
/// performed.
fn swap_phase<P: PointSet + ?Sized>(
    ps: &P,
    cfg: &BanditPamConfig,
    medoids: &mut [usize],
) -> usize {
    let n = ps.len();
    let k = cfg.km.k;
    let mut swaps = 0usize;
    for it in 0..cfg.km.max_swaps {
        let cache = MedoidCache::compute(ps, medoids);
        let candidates: Vec<usize> = (0..n).filter(|x| !medoids.contains(x)).collect();
        let n_arms = candidates.len() * k;
        let mut arms = SwapArms {
            ps,
            cache: &cache,
            candidates: &candidates,
            k,
            stats: ArmStats::new(n_arms),
            exact_rows: std::collections::HashMap::new(),
        };
        let bcfg = BanditConfig {
            delta: cfg.delta_scale / n_arms as f64,
            batch_size: cfg.batch_size,
            sampling: Sampling::Permutation,
            keep: 1,
            seed: cfg.km.seed ^ (0x50A9 + it as u64),
            threads: cfg.threads,
        };
        let r = successive_elimination(&mut arms, &bcfg);
        let best = r.best[0];
        // Exact improvement check for the chosen swap (n distance calls):
        // mirrors PAM's convergence criterion.
        let delta = arms.exact(best);
        if delta >= -1e-12 {
            break;
        }
        let (xi, mi) = (best / k, best % k);
        medoids[mi] = candidates[xi];
        swaps += 1;
    }
    swaps
}

/// Sort the medoids, compute the final loss, and assemble the result.
fn finish<P: PointSet + ?Sized>(
    ps: &P,
    medoids: Vec<usize>,
    swaps: usize,
    before: u64,
) -> KmResult {
    let mut sorted = medoids;
    sorted.sort_unstable();
    let cache = MedoidCache::compute(ps, &sorted);
    let dist_calls = ps.counter().get() - before;
    KmResult {
        loss: cache.loss(),
        medoids: sorted,
        swaps_performed: swaps,
        dist_calls,
        dist_calls_per_iter: dist_calls as f64 / (swaps + 1) as f64,
    }
}

/// BUILD arms (Eq. 2.5): one arm per candidate medoid x, reference pool =
/// all points, g_x(j) = (d(x,x_j) − d₁(j)) ∧ 0, or plain d(x,x_j) for the
/// first medoid.
struct BuildArms<'a, P: PointSet + ?Sized> {
    ps: &'a P,
    d1: &'a [f64],
    candidates: &'a [usize],
    first: bool,
    stats: ArmStats,
}

impl<'a, P: PointSet + ?Sized> BuildArms<'a, P> {
    /// Running per-arm sigma estimate (re-estimated continuously; §2.3.2).
    fn sigma(&self, arm: usize) -> f64 {
        self.stats.sigma(arm, 1e-9)
    }

    /// One arm's (Σv, Σv²) over a batch: ONE batched distance kernel
    /// call for the whole batch (candidate row gathered once), then the
    /// g-fold in batch order — same values, same order, same counter
    /// total as the scalar per-pull loop.
    fn arm_delta(&self, arm: usize, batch: &[usize]) -> (f64, f64) {
        let x = self.candidates[arm];
        let mut dists = scratch::f64_buf(batch.len());
        self.ps.dist_batch(x, batch, &mut dists);
        let mut s = 0.0;
        let mut s2 = 0.0;
        if self.first {
            for &d in dists.iter() {
                s += d;
                s2 += d * d;
            }
        } else {
            for (&d, &j) in dists.iter().zip(batch) {
                let v = (d - self.d1[j]).min(0.0);
                s += v;
                s2 += v * v;
            }
        }
        (s, s2)
    }

    /// Per-arm (Σv, Σv²) deltas for one shard of arms.
    fn deltas_for(&self, arms: &[usize], batch: &[usize]) -> Vec<(f64, f64)> {
        arms.iter().map(|&a| self.arm_delta(a, batch)).collect()
    }
}

impl<'a, P: PointSet + ?Sized> AdaptiveArms for BuildArms<'a, P> {
    fn n_arms(&self) -> usize {
        self.candidates.len()
    }

    fn ref_len(&self) -> usize {
        self.ps.len()
    }

    fn observe_shard(&mut self, arms: &[usize], batch: &[usize]) {
        let deltas = self.deltas_for(arms, batch);
        self.stats.push_deltas(arms, &deltas, batch.len() as u64);
    }

    fn observe_batch(&mut self, arms: &[usize], batch: &[usize], par: Option<ParCtx>) {
        let Some(p) = par else {
            self.observe_shard(arms, batch);
            return;
        };
        let this: &Self = self;
        let deltas = p.arm_deltas(arms, |a| this.arm_delta(a, batch));
        self.stats.push_deltas(arms, &deltas, batch.len() as u64);
    }

    fn estimate(&self, arm: usize) -> f64 {
        self.stats.mean(arm)
    }

    fn ci(&self, arm: usize, n_used: usize, delta: f64) -> f64 {
        if self.stats.count[arm] == 0 {
            return f64::INFINITY;
        }
        // Paper's Algorithm 2, line 8: C_x = sigma_x * sqrt(log(1/delta) / n).
        self.sigma(arm) * ((1.0 / delta).ln() / n_used.max(1) as f64).sqrt()
    }

    fn exact(&mut self, arm: usize) -> f64 {
        let n = self.ps.len();
        let idx = scratch::iota(n);
        let (s, _) = self.arm_delta(arm, &idx);
        s / n as f64
    }
}

/// SWAP arms (Eq. 2.6 with the FastPAM1 rewrite, Eq. A.1): arm (x, m_i)
/// indexed as `xi * k + mi`; a batch evaluates d(x, x_j) once per (x, j)
/// and updates all k arms of x — the O(k) saving of §A.1.1.
struct SwapArms<'a, P: PointSet + ?Sized> {
    ps: &'a P,
    cache: &'a MedoidCache,
    candidates: &'a [usize],
    k: usize,
    stats: ArmStats,
    /// Memoized full distance rows for the exact fallback: the k arms of a
    /// candidate x share one row (FastPAM1 sharing applies there too).
    exact_rows: std::collections::HashMap<usize, Vec<f64>>,
}

/// Contiguous runs of `arms` sharing one candidate x (`arms` is sorted, so
/// the k arms of a candidate are adjacent). Shards are built from whole
/// runs: FastPAM1's shared d(x, x_j) is evaluated exactly once per (x, j)
/// for any shard count.
fn group_ranges(arms: &[usize], k: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < arms.len() {
        let xi = arms[i] / k;
        let mut e = i;
        while e < arms.len() && arms[e] / k == xi {
            e += 1;
        }
        out.push((i, e));
        i = e;
    }
    out
}

impl<'a, P: PointSet + ?Sized> SwapArms<'a, P> {
    /// Running per-arm sigma estimate (re-estimated continuously; §2.3.2).
    fn sigma(&self, arm: usize) -> f64 {
        self.stats.sigma(arm, 1e-9)
    }

    /// g for swap (x, mi) at reference j, given the precomputed d(x, x_j).
    #[inline]
    fn g_from_d(&self, mi: usize, j: usize, dxj: f64) -> f64 {
        let without = if self.cache.nearest[j] == mi {
            self.cache.d2[j]
        } else {
            self.cache.d1[j]
        };
        dxj.min(without) - self.cache.d1[j]
    }

    /// Batch deltas for one candidate's arm group: ONE batched distance
    /// kernel call for the whole batch (the FastPAM1 sharing — the
    /// gathered d(x, ·) row serves all k arms of x), then the per-arm
    /// folds in batch order, exactly like the scalar loop.
    fn group_delta(&self, group: &[usize], batch: &[usize]) -> Vec<(f64, f64)> {
        let xi = group[0] / self.k;
        let x = self.candidates[xi];
        let mut dx = scratch::f64_buf(batch.len());
        self.ps.dist_batch(x, batch, &mut dx);
        let mut s = vec![0.0; group.len()];
        let mut s2 = vec![0.0; group.len()];
        for (&j, &dxj) in batch.iter().zip(dx.iter()) {
            for (gi, &a) in group.iter().enumerate() {
                let mi = a % self.k;
                let v = self.g_from_d(mi, j, dxj);
                s[gi] += v;
                s2[gi] += v * v;
            }
        }
        s.into_iter().zip(s2).collect()
    }

    /// Apply per-group delta vectors group-by-group in fixed arm order.
    fn apply(
        &mut self,
        arms: &[usize],
        ranges: &[(usize, usize)],
        deltas: &[Vec<(f64, f64)>],
        pulls: u64,
    ) {
        for (&(start, end), group_deltas) in ranges.iter().zip(deltas) {
            self.stats.push_deltas(&arms[start..end], group_deltas, pulls);
        }
    }
}

impl<'a, P: PointSet + ?Sized> AdaptiveArms for SwapArms<'a, P> {
    fn n_arms(&self) -> usize {
        self.candidates.len() * self.k
    }

    fn ref_len(&self) -> usize {
        self.ps.len()
    }

    fn observe_shard(&mut self, arms: &[usize], batch: &[usize]) {
        let ranges = group_ranges(arms, self.k);
        let deltas: Vec<Vec<(f64, f64)>> = ranges
            .iter()
            .map(|&(start, end)| self.group_delta(&arms[start..end], batch))
            .collect();
        self.apply(arms, &ranges, &deltas, batch.len() as u64);
    }

    fn observe_batch(&mut self, arms: &[usize], batch: &[usize], par: Option<ParCtx>) {
        let Some(p) = par else {
            self.observe_shard(arms, batch);
            return;
        };
        let ranges = group_ranges(arms, self.k);
        let this: &Self = self;
        let shard_deltas: Vec<Vec<Vec<(f64, f64)>>> =
            p.pool.map_shards(&ranges, p.shards, |range_shard| {
                range_shard
                    .iter()
                    .map(|&(start, end)| this.group_delta(&arms[start..end], batch))
                    .collect()
            });
        let deltas: Vec<Vec<(f64, f64)>> = shard_deltas.into_iter().flatten().collect();
        self.apply(arms, &ranges, &deltas, batch.len() as u64);
    }

    fn estimate(&self, arm: usize) -> f64 {
        self.stats.mean(arm)
    }

    fn ci(&self, arm: usize, n_used: usize, delta: f64) -> f64 {
        if self.stats.count[arm] == 0 {
            return f64::INFINITY;
        }
        // Paper's Algorithm 2, line 8: C_x = sigma_x * sqrt(log(1/delta) / n).
        self.sigma(arm) * ((1.0 / delta).ln() / n_used.max(1) as f64).sqrt()
    }

    fn exact(&mut self, arm: usize) -> f64 {
        let (xi, mi) = (arm / self.k, arm % self.k);
        let n = self.ps.len();
        if !self.exact_rows.contains_key(&xi) {
            let x = self.candidates[xi];
            let idx = scratch::iota(n);
            let mut row = vec![0f64; n];
            self.ps.dist_batch(x, &idx, &mut row);
            self.exact_rows.insert(xi, row);
        }
        let row = &self.exact_rows[&xi];
        let mut s = 0.0;
        for j in 0..n {
            s += self.g_from_d(mi, j, row[j]);
        }
        s / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distance::Metric;
    use crate::data::synthetic::{mnist_like_d, scrna_like};
    use crate::data::{Matrix, VecPointSet};
    use crate::kmedoids::pam::{pam, SwapMode};

    #[test]
    fn banditpam_matches_pam_on_line() {
        let rows = vec![
            vec![0.0f32],
            vec![1.0],
            vec![2.0],
            vec![10.0],
            vec![11.0],
            vec![12.0],
        ];
        let ps = VecPointSet::new(Matrix::from_rows(rows).expect("rectangular"), Metric::L2);
        let r = bandit_pam(&ps, &BanditPamConfig::new(2));
        assert_eq!(r.medoids, vec![1, 4]);
    }

    #[test]
    fn banditpam_agrees_with_pam_small_gaussian() {
        // The central claim: same medoids as PAM w.h.p. Tight δ on small n.
        let mut agree = 0;
        let trials = 6;
        for seed in 0..trials {
            let m = mnist_like_d(120, 30, seed);
            let ps = VecPointSet::new(m, Metric::L2);
            let cfg = KmConfig { k: 3, max_swaps: 16, seed };
            let exact = pam(&ps, &cfg, SwapMode::FastPam1);
            let mut bcfg = BanditPamConfig::new(3);
            bcfg.km = cfg.clone();
            bcfg.batch_size = 40;
            let bandit = bandit_pam(&ps, &bcfg);
            if exact.medoids == bandit.medoids {
                agree += 1;
            } else {
                // When trajectories diverge the losses must still be close
                // (distinct local minima of equal quality are possible).
                assert!(
                    bandit.loss <= exact.loss * 1.05,
                    "seed {seed}: bandit loss {} ≫ exact {}",
                    bandit.loss,
                    exact.loss
                );
            }
        }
        assert!(agree >= trials - 1, "only {agree}/{trials} exact agreements");
    }

    #[test]
    fn banditpam_l1_scrna_like() {
        let m = scrna_like(100, 40, 5);
        let ps = VecPointSet::new(m, Metric::L1);
        let cfg = KmConfig { k: 4, max_swaps: 20, seed: 5 };
        let exact = pam(&ps, &cfg, SwapMode::FastPam1);
        let mut bcfg = BanditPamConfig::new(4);
        bcfg.km = cfg;
        let bandit = bandit_pam(&ps, &bcfg);
        assert!(bandit.loss <= exact.loss * 1.05);
    }

    #[test]
    fn banditpam_fewer_calls_at_scale() {
        // At n = 600 BanditPAM should already beat the quadratic scan on
        // distance evaluations for the BUILD+SWAP pipeline.
        let n = 600;
        let m = mnist_like_d(n, 50, 11);
        let ps = VecPointSet::new(m, Metric::L2);
        let cfg = KmConfig { k: 3, max_swaps: 6, seed: 1 };

        ps.counter().reset();
        let _ = pam(&ps, &cfg, SwapMode::FastPam1);
        let exact_calls = ps.counter().get();

        ps.counter().reset();
        let mut bcfg = BanditPamConfig::new(3);
        bcfg.km = cfg;
        let _ = bandit_pam(&ps, &bcfg);
        let bandit_calls = ps.counter().get();

        // At n=600 the bandit already beats the quadratic scan; the margin
        // widens with n (the scaling experiments measure the slopes).
        assert!(
            bandit_calls < exact_calls,
            "bandit {bandit_calls} vs exact {exact_calls}"
        );
    }

    #[test]
    fn instrumentation_reports_sigmas_per_build_step() {
        let m = mnist_like_d(80, 20, 2);
        let ps = VecPointSet::new(m, Metric::L2);
        let (_, stats) = bandit_pam_instrumented(&ps, &BanditPamConfig::new(3));
        assert_eq!(stats.build_sigmas.len(), 3);
        // Paper Fig A.1: σ̂ drops sharply after the first medoid exists.
        let med = |xs: &Vec<f64>| crate::util::stats::quantile(xs, 0.5);
        assert!(
            med(&stats.build_sigmas[1]) < med(&stats.build_sigmas[0]),
            "σ̂ should shrink after first assignment"
        );
    }

    #[test]
    fn k1_is_exact_medoid() {
        // k=1: BanditPAM must find the true 1-medoid of a small set.
        let m = mnist_like_d(60, 10, 7);
        let ps = VecPointSet::new(m, Metric::L2);
        let r = bandit_pam(&ps, &BanditPamConfig::new(1));
        // brute force 1-medoid
        let mut best = (f64::INFINITY, usize::MAX);
        for x in 0..ps.len() {
            let mut s = 0.0;
            for j in 0..ps.len() {
                s += ps.dist(x, j);
            }
            if s < best.0 {
                best = (s, x);
            }
        }
        assert_eq!(r.medoids, vec![best.1]);
    }

    #[test]
    fn column_store_banditpam_bit_identical_to_matrix() {
        // Storage leg of the determinism contract: BanditPAM over a
        // ViewPointSet(ColumnStore, F32) reproduces the VecPointSet run
        // exactly — medoids, loss bits, swaps, distance-call totals — at
        // every thread count.
        use crate::store::{ColumnStore, StoreOptions, ViewPointSet};
        let m = mnist_like_d(130, 20, 17);
        let cs = std::sync::Arc::new(
            ColumnStore::from_matrix(
                &m,
                &StoreOptions { rows_per_chunk: 32, ..Default::default() },
            )
            .unwrap(),
        );
        let run = |columnar: bool, threads: usize| {
            let mut cfg = BanditPamConfig::new(3);
            cfg.km.seed = 17;
            cfg.threads = threads;
            let r = if columnar {
                bandit_pam(&ViewPointSet::new(cs.clone(), Metric::L2), &cfg)
            } else {
                bandit_pam(&VecPointSet::new(m.clone(), Metric::L2), &cfg)
            };
            (r.medoids, r.loss.to_bits(), r.swaps_performed, r.dist_calls)
        };
        let dense = run(false, 1);
        for threads in [1usize, 2, 4, 8] {
            assert_eq!(run(false, threads), dense, "matrix threads={threads}");
            assert_eq!(run(true, threads), dense, "column store threads={threads}");
        }
    }

    #[test]
    fn refresh_from_own_solution_is_a_cheap_fixed_point() {
        // Refreshing from the cold solution on unchanged data must return
        // the same medoids while skipping BUILD entirely.
        let m = mnist_like_d(150, 20, 19);
        let ps = VecPointSet::new(m, Metric::L2);
        let cfg = BanditPamConfig::new(3);
        ps.counter().reset();
        let cold = bandit_pam(&ps, &cfg);
        let cold_calls = ps.counter().get();
        ps.counter().reset();
        let warm = bandit_pam_refresh(&ps, &cold.medoids, &cfg);
        let warm_calls = ps.counter().get();
        assert_eq!(warm.medoids, cold.medoids);
        assert_eq!(warm.loss.to_bits(), cold.loss.to_bits());
        assert_eq!(warm.swaps_performed, 0, "already at a local optimum");
        assert!(
            warm_calls * 2 < cold_calls,
            "warm {warm_calls} should be < 50% of cold {cold_calls}"
        );
    }

    #[test]
    fn refresh_rebuilds_lost_medoids() {
        // A deleted medoid (out-of-range index after remapping) is
        // replaced via a warm BUILD step; the result still has k medoids
        // and near-cold quality.
        let m = mnist_like_d(120, 16, 23);
        let ps = VecPointSet::new(m, Metric::L2);
        let cfg = BanditPamConfig::new(3);
        let cold = bandit_pam(&ps, &cfg);
        // Drop one survivor, pass one out-of-range id and one duplicate.
        let prev = vec![cold.medoids[0], cold.medoids[0], usize::MAX, cold.medoids[2]];
        let warm = bandit_pam_refresh(&ps, &prev, &cfg);
        assert_eq!(warm.medoids.len(), 3);
        assert!(warm.medoids.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
        assert!(warm.loss <= cold.loss * 1.05, "warm {} vs cold {}", warm.loss, cold.loss);
    }

    #[test]
    fn parallel_banditpam_bit_identical_and_same_dist_calls() {
        // Tentpole acceptance: with a fixed seed, the sharded engine must
        // reproduce the sequential run exactly — medoids, loss bits, swap
        // count, AND distance-call totals (FastPAM1 sharing preserved by
        // group-aligned shards).
        let m = mnist_like_d(140, 24, 13);
        let ps = VecPointSet::new(m, Metric::L2);
        let run = |threads: usize| {
            ps.counter().reset();
            let mut cfg = BanditPamConfig::new(3);
            cfg.km.seed = 13;
            cfg.threads = threads;
            let r = bandit_pam(&ps, &cfg);
            (r.medoids, r.loss.to_bits(), r.swaps_performed, r.dist_calls)
        };
        let seq = run(1);
        for threads in [2usize, 4] {
            assert_eq!(run(threads), seq, "threads={threads} diverged");
        }
    }
}
