//! Exact PAM (Kaufman & Rousseeuw) and the FastPAM1 variant.
//!
//! PAM is the quality gold standard the thesis tracks: BanditPAM's claim
//! is *identical output* with O(n log n) instead of O(n²) distance
//! evaluations per iteration. Both the naive SWAP scan (O(k n²)) and the
//! FastPAM1 single-pass scan (O(n²), same output — §A.1.1) are here; the
//! BUILD step is shared.

use super::{KmConfig, KmResult, MedoidCache};
use crate::data::PointSet;

/// Which SWAP scan to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapMode {
    /// Evaluate all k(n−k) swaps independently: O(k n²) per iteration.
    Naive,
    /// FastPAM1: one pass over reference points computes all k deltas for
    /// each candidate x simultaneously — O(n²) per iteration, same output.
    FastPam1,
}

/// Run PAM: greedy BUILD then repeated best-swap SWAP until no
/// improvement or `cfg.max_swaps`.
pub fn pam<P: PointSet + ?Sized>(ps: &P, cfg: &KmConfig, mode: SwapMode) -> KmResult {
    let before = ps.counter().get();
    let medoids = build(ps, cfg.k);
    let (medoids, swaps) = swap_until_converged(ps, medoids, cfg.max_swaps, mode);
    let mut sorted = medoids.clone();
    sorted.sort_unstable();
    let cache = MedoidCache::compute(ps, &sorted);
    let dist_calls = ps.counter().get() - before;
    KmResult {
        loss: cache.loss(),
        medoids: sorted,
        swaps_performed: swaps,
        dist_calls,
        dist_calls_per_iter: dist_calls as f64 / (swaps + 1) as f64,
    }
}

/// Greedy BUILD (Eq. 2.3): add the point minimizing total loss, k times.
/// Exact: n(n−1)/2-ish distance evaluations per step (d₁ cached).
pub fn build<P: PointSet + ?Sized>(ps: &P, k: usize) -> Vec<usize> {
    let n = ps.len();
    assert!(k >= 1 && k <= n);
    let mut medoids: Vec<usize> = Vec::with_capacity(k);
    let mut d1 = vec![f64::INFINITY; n]; // min over current medoids
    for _ in 0..k {
        let mut best = (f64::INFINITY, usize::MAX);
        for x in 0..n {
            if medoids.contains(&x) {
                continue;
            }
            let mut total = 0.0;
            for j in 0..n {
                let dxj = ps.dist(x, j);
                total += dxj.min(d1[j]);
            }
            if total < best.0 {
                best = (total, x);
            }
        }
        let m = best.1;
        medoids.push(m);
        for j in 0..n {
            let d = ps.dist(m, j);
            if d < d1[j] {
                d1[j] = d;
            }
        }
    }
    medoids
}

/// Repeat best-improvement SWAP steps until converged. Returns final
/// medoids and the number of swaps performed.
pub fn swap_until_converged<P: PointSet + ?Sized>(
    ps: &P,
    mut medoids: Vec<usize>,
    max_swaps: usize,
    mode: SwapMode,
) -> (Vec<usize>, usize) {
    let mut swaps = 0;
    for _ in 0..max_swaps {
        let cache = MedoidCache::compute(ps, &medoids);
        let (delta, mi, x) = match mode {
            SwapMode::Naive => best_swap_naive(ps, &medoids, &cache),
            SwapMode::FastPam1 => best_swap_fastpam1(ps, &medoids, &cache),
        };
        if delta >= -1e-12 {
            break; // no improving swap
        }
        medoids[mi] = x;
        swaps += 1;
    }
    (medoids, swaps)
}

/// Naive SWAP scan (Eq. 2.4): for every medoid position × candidate,
/// recompute the post-swap loss contribution of every reference point.
fn best_swap_naive<P: PointSet + ?Sized>(
    ps: &P,
    medoids: &[usize],
    cache: &MedoidCache,
) -> (f64, usize, usize) {
    let n = ps.len();
    let mut best = (f64::INFINITY, usize::MAX, usize::MAX);
    for (mi, _m) in medoids.iter().enumerate() {
        for x in 0..n {
            if medoids.contains(&x) {
                continue;
            }
            // Δloss of swapping medoid position mi for x.
            let mut delta = 0.0;
            for j in 0..n {
                let dxj = ps.dist(x, j);
                let without_m = if cache.nearest[j] == mi { cache.d2[j] } else { cache.d1[j] };
                delta += dxj.min(without_m) - cache.d1[j];
            }
            if delta < best.0 {
                best = (delta, mi, x);
            }
        }
    }
    best
}

/// FastPAM1 SWAP scan (§A.1.1 / Eq. A.1): one pass over j per candidate x
/// computes the loss deltas for *all* k medoid positions at once using the
/// cached d₁, d₂ and cluster assignments.
fn best_swap_fastpam1<P: PointSet + ?Sized>(
    ps: &P,
    medoids: &[usize],
    cache: &MedoidCache,
) -> (f64, usize, usize) {
    let n = ps.len();
    let k = medoids.len();
    let mut best = (f64::INFINITY, usize::MAX, usize::MAX);
    let mut delta = vec![0f64; k];
    for x in 0..n {
        if medoids.contains(&x) {
            continue;
        }
        delta.iter_mut().for_each(|d| *d = 0.0);
        // Shared accumulator: removing medoid m only changes points in C_m.
        let mut shared = 0.0; // Σ_j min(dxj, d1_j) − d1_j  (m ∉ nearest(j))
        for j in 0..n {
            let dxj = ps.dist(x, j);
            let nj = cache.nearest[j];
            // For m ≠ nearest(j): contribution min(dxj, d1) − d1.
            let other = dxj.min(cache.d1[j]) - cache.d1[j];
            shared += other;
            // For m = nearest(j): contribution min(dxj, d2) − d1, replacing
            // the `other` term accounted in `shared`.
            delta[nj] += (dxj.min(cache.d2[j]) - cache.d1[j]) - other;
        }
        for mi in 0..k {
            let total = shared + delta[mi];
            if total < best.0 {
                best = (total, mi, x);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distance::Metric;
    use crate::data::synthetic::mnist_like_d;
    use crate::data::{Matrix, VecPointSet};
    use crate::kmedoids::loss;

    fn line_clusters() -> VecPointSet {
        let rows = vec![
            vec![0.0f32],
            vec![1.0],
            vec![2.0],
            vec![10.0],
            vec![11.0],
            vec![12.0],
        ];
        VecPointSet::new(Matrix::from_rows(rows).expect("rectangular"), Metric::L2)
    }

    #[test]
    fn build_picks_greedy_optima() {
        let ps = line_clusters();
        let m = build(&ps, 2);
        // Greedy BUILD first picks the global 1-medoid (point 2, sum of
        // distances 30), then point 11 (index 4). SWAP later refines 2 → 1.
        assert_eq!(m, vec![2, 4]);
    }

    #[test]
    fn pam_converges_to_optimal_on_line() {
        let ps = line_clusters();
        let r = pam(&ps, &KmConfig::new(2), SwapMode::Naive);
        assert_eq!(r.medoids, vec![1, 4]);
        assert!((r.loss - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fastpam1_agrees_with_naive() {
        // The thesis' guarantee: FastPAM1 returns the *same* result as PAM.
        for seed in 0..5 {
            let m = mnist_like_d(60, 20, seed);
            let ps = VecPointSet::new(m, Metric::L2);
            let cfg = KmConfig { k: 3, max_swaps: 20, seed };
            let a = pam(&ps, &cfg, SwapMode::Naive);
            let b = pam(&ps, &cfg, SwapMode::FastPam1);
            assert_eq!(a.medoids, b.medoids, "seed {seed}");
            assert!((a.loss - b.loss).abs() < 1e-9);
        }
    }

    #[test]
    fn fastpam1_uses_fewer_distance_calls() {
        let m = mnist_like_d(80, 20, 3);
        let ps = VecPointSet::new(m, Metric::L2);
        let cfg = KmConfig { k: 4, max_swaps: 20, seed: 0 };
        ps.counter().reset();
        let _ = pam(&ps, &cfg, SwapMode::Naive);
        let naive_calls = ps.counter().get();
        ps.counter().reset();
        let _ = pam(&ps, &cfg, SwapMode::FastPam1);
        let fp1_calls = ps.counter().get();
        assert!(
            fp1_calls * 2 < naive_calls,
            "FastPAM1 {fp1_calls} vs naive {naive_calls}"
        );
    }

    #[test]
    fn swap_never_increases_loss() {
        let m = mnist_like_d(50, 10, 9);
        let ps = VecPointSet::new(m, Metric::L1);
        let built = build(&ps, 3);
        let loss_before = loss(&ps, &built);
        let (after, _) = swap_until_converged(&ps, built, 10, SwapMode::FastPam1);
        let loss_after = loss(&ps, &after);
        assert!(loss_after <= loss_before + 1e-9);
    }

    #[test]
    fn k_equals_n_zero_loss() {
        let ps = line_clusters();
        let r = pam(&ps, &KmConfig::new(6), SwapMode::FastPam1);
        assert!(r.loss.abs() < 1e-12);
        assert_eq!(r.medoids, vec![0, 1, 2, 3, 4, 5]);
    }
}
