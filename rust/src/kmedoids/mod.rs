//! Chapter 2 — k-medoids clustering.
//!
//! Implements the full comparison set of the thesis' evaluation:
//! * [`pam`] — exact Partitioning Around Medoids (BUILD + SWAP), plus the
//!   FastPAM1 variant (identical output, O(k) cheaper SWAP scan);
//! * [`banditpam`] — the paper's contribution: each BUILD/SWAP search run
//!   as a best-arm identification problem on the shared engine;
//! * [`baselines`] — CLARANS, Voronoi iteration, CLARA (quality-sacrificing
//!   randomized baselines of Fig 2.1(a)).
//!
//! Every algorithm works over any [`crate::data::PointSet`] — dense
//! vectors under l1/l2/cosine or program trees under edit distance — and
//! reports the number of distance evaluations, the paper's complexity
//! metric.

pub mod banditpam;
pub mod baselines;
pub mod pam;

use crate::data::PointSet;

/// Common configuration for all k-medoids solvers.
#[derive(Clone, Debug)]
pub struct KmConfig {
    pub k: usize,
    /// Hard cap T on SWAP iterations (Remark 1 of §2.4; empirically O(k)).
    pub max_swaps: usize,
    pub seed: u64,
}

impl KmConfig {
    pub fn new(k: usize) -> Self {
        KmConfig { k, max_swaps: 4 * k + 4, seed: 42 }
    }
}

/// Result common to every solver.
#[derive(Clone, Debug)]
pub struct KmResult {
    /// Selected medoid indices (sorted).
    pub medoids: Vec<usize>,
    /// Final loss L(M) = Σ_j min_m d(m, x_j)  (Eq. 2.1).
    pub loss: f64,
    /// SWAP iterations actually performed.
    pub swaps_performed: usize,
    /// Total distance evaluations.
    pub dist_calls: u64,
    /// Distance evaluations divided by (swaps + 1) — the paper's
    /// "per iteration" normalization (§2.5.2).
    pub dist_calls_per_iter: f64,
}

impl KmResult {
    /// FNV-1a digest of the *answer* (medoids + exact loss bits) — what
    /// the perf-gate pins so a cost regression fix can never silently
    /// change the clustering. Cost fields are deliberately excluded:
    /// they are tracked as counters, not as part of the answer.
    pub fn digest(&self) -> u64 {
        crate::util::digest::fnv1a_u64s(
            self.medoids
                .iter()
                .map(|&m| m as u64)
                .chain(std::iter::once(self.loss.to_bits())),
        )
    }
}

/// Exact clustering loss (Eq. 2.1). Counts its distance evaluations.
/// Evaluates one batched [`PointSet::dist_batch`] sweep per medoid (the
/// medoid's row gathered once; chunked stores serve block-scheduled
/// reads) — same k·n evaluation count and, per point, the same
/// medoid-order min fold as the scalar loop.
pub fn loss<P: PointSet + ?Sized>(ps: &P, medoids: &[usize]) -> f64 {
    let n = ps.len();
    let idx = crate::kernels::scratch::iota(n);
    let mut dists = crate::kernels::scratch::f64_buf(n);
    let mut best = vec![f64::INFINITY; n];
    for &m in medoids {
        ps.dist_batch(m, &idx, &mut dists);
        for (slot, &d) in best.iter_mut().zip(dists.iter()) {
            if d < *slot {
                *slot = d;
            }
        }
    }
    best.iter().sum()
}

/// Cached nearest / second-nearest medoid distances for every point —
/// the d₁/d₂ cache both PAM and BanditPAM maintain (§2.2.1, §A.1.1).
#[derive(Clone, Debug)]
pub struct MedoidCache {
    /// Index *into the medoid list* of each point's nearest medoid.
    pub nearest: Vec<usize>,
    /// Distance to nearest medoid (d₁).
    pub d1: Vec<f64>,
    /// Distance to second-nearest medoid (d₂; ∞ when k = 1).
    pub d2: Vec<f64>,
}

impl MedoidCache {
    /// Build the cache with k·n distance evaluations — one batched
    /// [`PointSet::dist_batch`] sweep per medoid. Each point still sees
    /// its medoid distances in medoid order, so the d₁/d₂/nearest state
    /// is identical to the scalar double loop.
    pub fn compute<P: PointSet + ?Sized>(ps: &P, medoids: &[usize]) -> Self {
        let n = ps.len();
        let mut nearest = vec![usize::MAX; n];
        let mut d1 = vec![f64::INFINITY; n];
        let mut d2 = vec![f64::INFINITY; n];
        let idx = crate::kernels::scratch::iota(n);
        let mut dists = crate::kernels::scratch::f64_buf(n);
        for (mi, &m) in medoids.iter().enumerate() {
            ps.dist_batch(m, &idx, &mut dists);
            for (j, &d) in dists.iter().enumerate() {
                if d < d1[j] {
                    d2[j] = d1[j];
                    d1[j] = d;
                    nearest[j] = mi;
                } else if d < d2[j] {
                    d2[j] = d;
                }
            }
        }
        MedoidCache { nearest, d1, d2 }
    }

    /// Total loss from the cache.
    pub fn loss(&self) -> f64 {
        self.d1.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distance::Metric;
    use crate::data::{Matrix, VecPointSet};

    fn tiny() -> VecPointSet {
        // Two obvious clusters on a line: {0,1,2} and {10,11,12}.
        let rows = vec![
            vec![0.0f32],
            vec![1.0],
            vec![2.0],
            vec![10.0],
            vec![11.0],
            vec![12.0],
        ];
        VecPointSet::new(Matrix::from_rows(rows).expect("rectangular"), Metric::L2)
    }

    #[test]
    fn loss_of_true_medoids() {
        let ps = tiny();
        // medoids 1 and 4 (the centers): loss = 1+0+1 + 1+0+1 = 4
        assert!((loss(&ps, &[1, 4]) - 4.0).abs() < 1e-9);
        // worse medoids cost more
        assert!(loss(&ps, &[0, 3]) > 4.0);
    }

    #[test]
    fn cache_matches_direct_loss() {
        let ps = tiny();
        let cache = MedoidCache::compute(&ps, &[1, 4]);
        assert!((cache.loss() - loss(&ps, &[1, 4])).abs() < 1e-9);
        assert_eq!(cache.nearest[0], 0);
        assert_eq!(cache.nearest[5], 1);
        // d2 of point 0 is distance to medoid 4 = 11
        assert!((cache.d2[0] - 11.0).abs() < 1e-9);
    }

    #[test]
    fn loss_counts_distance_calls() {
        let ps = tiny();
        ps.counter().reset();
        let _ = loss(&ps, &[1, 4]);
        assert_eq!(ps.counter().get(), 12); // 6 points × 2 medoids
    }
}
