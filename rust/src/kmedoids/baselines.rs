//! Quality-sacrificing randomized k-medoids baselines of Fig. 2.1(a):
//! CLARANS (Ng & Han), Voronoi iteration ("k-means-style" alternation,
//! Park & Jun), and CLARA (PAM on subsamples, Kaufman & Rousseeuw).
//! These trade clustering loss for speed — the thesis shows they land
//! noticeably above PAM's loss while BanditPAM matches it exactly.

use super::{loss, KmConfig, KmResult, MedoidCache};
use crate::data::PointSet;
use crate::kmedoids::pam::{pam, SwapMode};
use crate::util::rng::Rng;

/// CLARANS: randomized local search over the swap graph. `num_local`
/// restarts; from each start, up to `max_neighbors` random swap proposals
/// are tried, accepting any improvement and resetting the counter.
pub fn clarans<P: PointSet + ?Sized>(
    ps: &P,
    cfg: &KmConfig,
    num_local: usize,
    max_neighbors: usize,
) -> KmResult {
    let before = ps.counter().get();
    let n = ps.len();
    let k = cfg.k;
    let mut rng = Rng::new(cfg.seed);
    let mut best_medoids: Vec<usize> = Vec::new();
    let mut best_loss = f64::INFINITY;
    let mut total_swaps = 0usize;

    for _restart in 0..num_local {
        let mut medoids = rng.sample_without_replacement(n, k);
        let mut cur_loss = loss(ps, &medoids);
        let mut tries = 0;
        while tries < max_neighbors {
            // Random neighbor: swap one random medoid with one random
            // non-medoid.
            let mi = rng.below(k);
            let mut x = rng.below(n);
            while medoids.contains(&x) {
                x = rng.below(n);
            }
            let old = medoids[mi];
            medoids[mi] = x;
            let new_loss = loss(ps, &medoids);
            if new_loss < cur_loss - 1e-12 {
                cur_loss = new_loss;
                total_swaps += 1;
                tries = 0;
            } else {
                medoids[mi] = old;
                tries += 1;
            }
        }
        if cur_loss < best_loss {
            best_loss = cur_loss;
            best_medoids = medoids.clone();
        }
    }

    best_medoids.sort_unstable();
    let dist_calls = ps.counter().get() - before;
    KmResult {
        loss: best_loss,
        medoids: best_medoids,
        swaps_performed: total_swaps,
        dist_calls,
        dist_calls_per_iter: dist_calls as f64 / (total_swaps + 1) as f64,
    }
}

/// Voronoi iteration (Park & Jun / "k-medoids the k-means way"):
/// alternate (1) assign points to the nearest medoid, (2) recompute each
/// cluster's medoid exactly. Converges to a local optimum that is often
/// worse than PAM's (cluster-local moves only).
pub fn voronoi<P: PointSet + ?Sized>(ps: &P, cfg: &KmConfig, max_iters: usize) -> KmResult {
    let before = ps.counter().get();
    let n = ps.len();
    let k = cfg.k;
    let mut rng = Rng::new(cfg.seed);
    let mut medoids = rng.sample_without_replacement(n, k);
    let mut iters = 0usize;

    for _ in 0..max_iters {
        iters += 1;
        // Assign.
        let cache = MedoidCache::compute(ps, &medoids);
        // Recompute medoid of each cluster.
        let mut clusters: Vec<Vec<usize>> = vec![Vec::new(); k];
        for j in 0..n {
            clusters[cache.nearest[j]].push(j);
        }
        let mut changed = false;
        for (ci, cluster) in clusters.iter().enumerate() {
            if cluster.is_empty() {
                continue;
            }
            let mut best = (f64::INFINITY, medoids[ci]);
            for &cand in cluster {
                let mut s = 0.0;
                for &j in cluster {
                    s += ps.dist(cand, j);
                }
                if s < best.0 {
                    best = (s, cand);
                }
            }
            if best.1 != medoids[ci] {
                medoids[ci] = best.1;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    medoids.sort_unstable();
    let final_loss = loss(ps, &medoids);
    let dist_calls = ps.counter().get() - before;
    KmResult {
        loss: final_loss,
        medoids,
        swaps_performed: iters,
        dist_calls,
        dist_calls_per_iter: dist_calls as f64 / iters.max(1) as f64,
    }
}

/// CLARA: run PAM on `n_samples` random subsets of size `sample_size`
/// (classically 40 + 2k) and keep the subset solution with the best
/// *full-data* loss.
pub fn clara<P: PointSet + ?Sized>(
    ps: &P,
    cfg: &KmConfig,
    n_samples: usize,
    sample_size: usize,
) -> KmResult {
    let before = ps.counter().get();
    let n = ps.len();
    let mut rng = Rng::new(cfg.seed);
    let mut best_medoids: Vec<usize> = Vec::new();
    let mut best_loss = f64::INFINITY;

    for _ in 0..n_samples {
        let sample = rng.sample_without_replacement(n, sample_size.min(n));
        let sub = SubsetPointSet { inner: ps, idx: &sample };
        let sub_cfg = KmConfig { k: cfg.k, max_swaps: cfg.max_swaps, seed: cfg.seed };
        let sub_res = pam(&sub, &sub_cfg, SwapMode::FastPam1);
        let medoids: Vec<usize> = sub_res.medoids.iter().map(|&i| sample[i]).collect();
        let l = loss(ps, &medoids);
        if l < best_loss {
            best_loss = l;
            best_medoids = medoids;
        }
    }

    best_medoids.sort_unstable();
    let dist_calls = ps.counter().get() - before;
    KmResult {
        loss: best_loss,
        medoids: best_medoids,
        swaps_performed: n_samples,
        dist_calls,
        dist_calls_per_iter: dist_calls as f64 / n_samples.max(1) as f64,
    }
}

/// A view of a PointSet restricted to a subset of indices (for CLARA).
struct SubsetPointSet<'a, P: PointSet + ?Sized> {
    inner: &'a P,
    idx: &'a [usize],
}

impl<'a, P: PointSet + ?Sized> PointSet for SubsetPointSet<'a, P> {
    fn len(&self) -> usize {
        self.idx.len()
    }

    fn dist(&self, i: usize, j: usize) -> f64 {
        self.inner.dist(self.idx[i], self.idx[j])
    }

    fn counter(&self) -> &crate::metrics::OpCounter {
        self.inner.counter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distance::Metric;
    use crate::data::synthetic::mnist_like_d;
    use crate::data::{Matrix, VecPointSet};

    fn line_clusters() -> VecPointSet {
        let rows = vec![
            vec![0.0f32],
            vec![1.0],
            vec![2.0],
            vec![10.0],
            vec![11.0],
            vec![12.0],
        ];
        VecPointSet::new(Matrix::from_rows(rows).expect("rectangular"), Metric::L2)
    }

    #[test]
    fn clarans_finds_good_solution_on_easy_data() {
        let ps = line_clusters();
        let r = clarans(&ps, &KmConfig::new(2), 3, 30);
        assert!((r.loss - 4.0).abs() < 1e-9, "loss {}", r.loss);
    }

    #[test]
    fn voronoi_converges() {
        let ps = line_clusters();
        let r = voronoi(&ps, &KmConfig::new(2), 50);
        assert!(r.loss <= 8.0, "voronoi loss {} unreasonable", r.loss);
        assert!(r.swaps_performed < 50, "should converge before cap");
    }

    #[test]
    fn clara_close_to_pam_on_small_data() {
        let m = mnist_like_d(100, 10, 3);
        let ps = VecPointSet::new(m, Metric::L2);
        let cfg = KmConfig::new(3);
        let exact = pam(&ps, &cfg, SwapMode::FastPam1);
        let cl = clara(&ps, &cfg, 4, 50);
        assert!(cl.loss >= exact.loss - 1e-9, "CLARA can't beat PAM's optimum");
        assert!(cl.loss <= exact.loss * 1.5, "CLARA loss {} way off {}", cl.loss, exact.loss);
    }

    #[test]
    fn baselines_never_beat_pam_materially() {
        // Fig 2.1(a)'s ordering: PAM ≤ {CLARANS, Voronoi} on average.
        let mut pam_wins = 0;
        for seed in 0..4 {
            let m = mnist_like_d(80, 10, seed);
            let ps = VecPointSet::new(m, Metric::L2);
            let cfg = KmConfig { k: 3, max_swaps: 12, seed };
            let exact = pam(&ps, &cfg, SwapMode::FastPam1);
            let v = voronoi(&ps, &cfg, 30);
            if exact.loss <= v.loss + 1e-9 {
                pam_wins += 1;
            }
        }
        assert!(pam_wins >= 3, "PAM should dominate Voronoi ({pam_wins}/4)");
    }
}
