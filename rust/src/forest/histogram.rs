//! Feature histograms and impurity metrics — the substrate of Chapter 3.
//!
//! Node-splitting in modern tree learners (XGBoost/LightGBM-style, §3.2)
//! bins each feature into `T` bins and only considers bin edges as
//! thresholds. A histogram accumulates either per-class counts
//! (classification) or (count, Σy, Σy²) moments (regression); both
//! support O(T·K) best-threshold scans via prefix sums. Every insertion is
//! counted — "number of histogram insertions" is the paper's budget and
//! complexity metric (Tables 3.1–3.5).

use crate::metrics::OpCounter;

/// Impurity criterion (Eq. 3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Impurity {
    Gini,
    Entropy,
    /// Mean squared error (regression).
    Mse,
}

/// Gini impurity of a class-count vector.
pub fn gini(counts: &[f64], total: f64) -> f64 {
    if total <= 0.0 {
        return 0.0;
    }
    let mut s = 0.0;
    for &c in counts {
        let p = c / total;
        s += p * p;
    }
    1.0 - s
}

/// Entropy (bits) of a class-count vector.
pub fn entropy(counts: &[f64], total: f64) -> f64 {
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0.0 {
            let p = c / total;
            h -= p * p.log2();
        }
    }
    h
}

/// Bin-edge layout for one feature.
#[derive(Clone, Debug)]
pub struct BinEdges {
    /// `T+1` ascending edges; bin i covers [edges[i], edges[i+1]).
    pub edges: Vec<f32>,
}

impl BinEdges {
    /// Equal-width bins over [lo, hi] (RF / Random Patches; §3.2).
    pub fn equal_width(lo: f32, hi: f32, t: usize) -> Self {
        assert!(t >= 1);
        let span = (hi - lo).max(1e-12);
        let edges = (0..=t)
            .map(|i| lo + span * (i as f32) / (t as f32))
            .collect();
        BinEdges { edges }
    }

    /// Random edges uniform over [lo, hi] (ExtraTrees; §3.5 baselines).
    pub fn random(lo: f32, hi: f32, t: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let span = (hi - lo).max(1e-12);
        let mut inner: Vec<f32> = (0..t.saturating_sub(1))
            .map(|_| lo + span * rng.f32())
            .collect();
        inner.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut edges = Vec::with_capacity(t + 1);
        edges.push(lo);
        edges.extend(inner);
        edges.push(hi + span * 1e-6);
        BinEdges { edges }
    }

    pub fn n_bins(&self) -> usize {
        self.edges.len() - 1
    }

    /// Bin index for a value. Equal-width layout is O(1) (direct index);
    /// uneven layouts binary-search (O(log T)) — exactly the trade-off
    /// discussed in §3.5.2.
    #[inline]
    pub fn bin_of(&self, v: f32) -> usize {
        let t = self.n_bins();
        let lo = self.edges[0];
        let hi = self.edges[t];
        if v <= lo {
            return 0;
        }
        if v >= hi {
            return t - 1;
        }
        // Direct index assuming equal width; verify and fall back to
        // binary search for uneven (ExtraTrees) layouts.
        let guess = (((v - lo) / (hi - lo)) * t as f32) as usize;
        let guess = guess.min(t - 1);
        if self.edges[guess] <= v && v < self.edges[guess + 1] {
            return guess;
        }
        // Binary search: find rightmost edge ≤ v.
        match self.edges.binary_search_by(|e| e.partial_cmp(&v).unwrap()) {
            Ok(i) => i.min(t - 1),
            Err(i) => i.saturating_sub(1).min(t - 1),
        }
    }
}

/// A classification histogram: per-bin per-class counts.
#[derive(Clone, Debug)]
pub struct ClassHistogram {
    pub edges: BinEdges,
    pub k: usize,
    /// counts[bin * k + class]
    pub counts: Vec<f64>,
    pub total: f64,
}

impl ClassHistogram {
    pub fn new(edges: BinEdges, k: usize) -> Self {
        let t = edges.n_bins();
        ClassHistogram { edges, k, counts: vec![0.0; t * k], total: 0.0 }
    }

    /// Insert one (value, class) pair. Counted.
    #[inline]
    pub fn insert(&mut self, v: f32, class: usize, counter: &OpCounter) {
        counter.incr();
        self.insert_uncounted(v, class);
    }

    /// Insert without touching the counter — the batched fill path
    /// ([`ClassHistogram::fill`]) counts once per run instead of once per
    /// element; totals are identical.
    #[inline]
    pub fn insert_uncounted(&mut self, v: f32, class: usize) {
        let b = self.edges.bin_of(v);
        self.counts[b * self.k + class] += 1.0;
        self.total += 1.0;
    }

    /// Batched fill: insert `vals` (with their classes) in order, counted
    /// as `vals.len()` insertions in one counter add. Bin state is
    /// identical to the scalar insert loop — integer counts accumulated
    /// in the same order.
    pub fn fill(
        &mut self,
        vals: &[f32],
        classes: impl Iterator<Item = usize>,
        counter: &OpCounter,
    ) {
        counter.add(vals.len() as u64);
        for (&v, class) in vals.iter().zip(classes) {
            self.insert_uncounted(v, class);
        }
    }

    /// Batched fill straight from an I8 chunk run
    /// ([`crate::store::ColBlock::I8`]): bins, counts, and counter totals
    /// are identical to decoding each code to f32 and calling
    /// [`ClassHistogram::fill`] — `bin_of(header.decode(u))` is the same
    /// expression — so split decisions and answer digests are unchanged.
    /// The decode runs at most 256 times per run (a code→bin LUT)
    /// instead of once per element.
    pub fn fill_i8(
        &mut self,
        h: &crate::kernels::quant::I8Header,
        codes: &[u8],
        classes: impl Iterator<Item = usize>,
        counter: &OpCounter,
    ) {
        counter.add(codes.len() as u64);
        if codes.len() >= 256 {
            let mut lut = [0usize; 256];
            for (u, slot) in lut.iter_mut().enumerate() {
                *slot = self.edges.bin_of(h.decode(u as u8));
            }
            for (&u, class) in codes.iter().zip(classes) {
                self.counts[lut[u as usize] * self.k + class] += 1.0;
                self.total += 1.0;
            }
        } else {
            for (&u, class) in codes.iter().zip(classes) {
                self.insert_uncounted(h.decode(u), class);
            }
        }
    }

    /// Weighted-impurity objective μ_ft (Eq. 3.3, normalized by total) and
    /// its delta-method standard error (§B.3) for *every* threshold in one
    /// prefix-sum scan. Threshold index t means "split after bin t"
    /// (t ∈ 0..T−1). Returns (mu, se) pairs.
    pub fn scan_thresholds(&self, imp: Impurity) -> Vec<(f64, f64)> {
        let t_bins = self.edges.n_bins();
        let k = self.k;
        let n = self.total;
        let mut out = Vec::with_capacity(t_bins.saturating_sub(1));
        if n <= 0.0 {
            out.resize(t_bins.saturating_sub(1), (f64::INFINITY, f64::INFINITY));
            return out;
        }
        // Totals per class.
        let mut tot = vec![0.0; k];
        for b in 0..t_bins {
            for c in 0..k {
                tot[c] += self.counts[b * k + c];
            }
        }
        let mut left = vec![0.0; k];
        #[allow(unused_assignments)]
        let mut left_n;
        for t in 0..t_bins.saturating_sub(1) {
            for c in 0..k {
                left[c] += self.counts[t * k + c];
            }
            left_n = left.iter().sum();
            let right_n = n - left_n;
            let mut right = vec![0.0; k];
            for c in 0..k {
                right[c] = tot[c] - left[c];
            }
            let (wl, wr) = (left_n / n, right_n / n);
            let mu = match imp {
                Impurity::Gini => wl * gini(&left, left_n) + wr * gini(&right, right_n),
                Impurity::Entropy => {
                    wl * entropy(&left, left_n) + wr * entropy(&right, right_n)
                }
                Impurity::Mse => unreachable!("Mse on classification histogram"),
            };
            let se = delta_method_se(imp, &left, left_n, &right, right_n, n);
            out.push((mu, se));
        }
        out
    }
}

/// Delta-method standard error of the plug-in weighted impurity (§B.3):
/// Var ≈ (1/n)·[Σ q·g² − (Σ q·g)²] with q the joint (side, class)
/// proportions and g = ∂μ/∂q.
fn delta_method_se(
    imp: Impurity,
    left: &[f64],
    left_n: f64,
    right: &[f64],
    right_n: f64,
    n: f64,
) -> f64 {
    if n <= 1.0 {
        return f64::INFINITY;
    }
    let mut e_g2 = 0.0;
    let mut e_g = 0.0;
    let mut side = |counts: &[f64], side_n: f64| {
        if side_n <= 0.0 {
            return;
        }
        let w = side_n / n;
        match imp {
            Impurity::Gini => {
                // μ_side = w − Σ_k q²/w ;  ∂/∂q_k = 1 − 2p_k + Σ_j p_j²
                let s2: f64 = counts.iter().map(|&c| (c / side_n) * (c / side_n)).sum();
                for &c in counts {
                    let q = c / n;
                    let p = c / side_n;
                    let g = 1.0 - 2.0 * p + s2;
                    e_g2 += q * g * g;
                    e_g += q * g;
                }
            }
            Impurity::Entropy => {
                // ∂/∂q_k = −log2(p_k)
                for &c in counts {
                    if c > 0.0 {
                        let q = c / n;
                        let p = c / side_n;
                        let g = -(p.log2());
                        e_g2 += q * g * g;
                        e_g += q * g;
                    }
                }
            }
            Impurity::Mse => unreachable!(),
        }
        let _ = w;
    };
    side(left, left_n);
    side(right, right_n);
    ((e_g2 - e_g * e_g).max(0.0) / n).sqrt()
}

/// A regression histogram: per-bin (count, Σy, Σy²).
#[derive(Clone, Debug)]
pub struct MomentHistogram {
    pub edges: BinEdges,
    /// moments[bin] = (count, sum, sumsq)
    pub moments: Vec<(f64, f64, f64)>,
    pub total: f64,
}

impl MomentHistogram {
    pub fn new(edges: BinEdges) -> Self {
        let t = edges.n_bins();
        MomentHistogram { edges, moments: vec![(0.0, 0.0, 0.0); t], total: 0.0 }
    }

    #[inline]
    pub fn insert(&mut self, v: f32, y: f64, counter: &OpCounter) {
        counter.incr();
        self.insert_uncounted(v, y);
    }

    /// Insert without touching the counter (see
    /// [`ClassHistogram::insert_uncounted`]).
    #[inline]
    pub fn insert_uncounted(&mut self, v: f32, y: f64) {
        let b = self.edges.bin_of(v);
        let m = &mut self.moments[b];
        m.0 += 1.0;
        m.1 += y;
        m.2 += y * y;
        self.total += 1.0;
    }

    /// Batched fill: insert `vals` (with their targets) in order, counted
    /// as `vals.len()` insertions in one counter add. Moment sums
    /// accumulate in the same order as the scalar insert loop, so the
    /// f64 state is bit-identical.
    pub fn fill(&mut self, vals: &[f32], ys: impl Iterator<Item = f64>, counter: &OpCounter) {
        counter.add(vals.len() as u64);
        for (&v, y) in vals.iter().zip(ys) {
            self.insert_uncounted(v, y);
        }
    }

    /// Batched fill straight from an I8 chunk run (see
    /// [`ClassHistogram::fill_i8`]): bins and moment sums accumulate in
    /// the same order as decode-then-[`MomentHistogram::fill`], so the
    /// f64 state is bit-identical.
    pub fn fill_i8(
        &mut self,
        h: &crate::kernels::quant::I8Header,
        codes: &[u8],
        ys: impl Iterator<Item = f64>,
        counter: &OpCounter,
    ) {
        counter.add(codes.len() as u64);
        if codes.len() >= 256 {
            let mut lut = [0usize; 256];
            for (u, slot) in lut.iter_mut().enumerate() {
                *slot = self.edges.bin_of(h.decode(u as u8));
            }
            for (&u, y) in codes.iter().zip(ys) {
                let m = &mut self.moments[lut[u as usize]];
                m.0 += 1.0;
                m.1 += y;
                m.2 += y * y;
                self.total += 1.0;
            }
        } else {
            for (&u, y) in codes.iter().zip(ys) {
                self.insert_uncounted(h.decode(u), y);
            }
        }
    }

    /// Weighted child MSE for every threshold + a CI scale: the standard
    /// error of the weighted-variance plug-in, approximated by
    /// √(Var̂(y)·2/n) per §B.3's "derived similarly" remark.
    pub fn scan_thresholds(&self) -> Vec<(f64, f64)> {
        let t_bins = self.edges.n_bins();
        let n = self.total;
        let mut out = Vec::with_capacity(t_bins.saturating_sub(1));
        if n <= 0.0 {
            out.resize(t_bins.saturating_sub(1), (f64::INFINITY, f64::INFINITY));
            return out;
        }
        let (mut tn, mut ts, mut tq) = (0.0, 0.0, 0.0);
        for &(c, s, q) in &self.moments {
            tn += c;
            ts += s;
            tq += q;
        }
        let var_y = (tq / n - (ts / n) * (ts / n)).max(0.0);
        let (mut ln, mut ls, mut lq) = (0.0, 0.0, 0.0);
        for t in 0..t_bins.saturating_sub(1) {
            let (c, s, q) = self.moments[t];
            ln += c;
            ls += s;
            lq += q;
            let rn = tn - ln;
            let rs = ts - ls;
            let rq = tq - lq;
            let child_sse = |cn: f64, cs: f64, cq: f64| {
                if cn <= 0.0 {
                    0.0
                } else {
                    (cq - cs * cs / cn).max(0.0)
                }
            };
            // μ = weighted child variance = (SSE_L + SSE_R) / n.
            let mu = (child_sse(ln, ls, lq) + child_sse(rn, rs, rq)) / n;
            let se = (var_y * 2.0 / n).sqrt() * var_y.sqrt().max(1.0);
            out.push((mu, se));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gini_entropy_extremes() {
        assert!((gini(&[10.0, 0.0], 10.0) - 0.0).abs() < 1e-12);
        assert!((gini(&[5.0, 5.0], 10.0) - 0.5).abs() < 1e-12);
        assert!((entropy(&[5.0, 5.0], 10.0) - 1.0).abs() < 1e-12);
        assert!(entropy(&[10.0, 0.0], 10.0).abs() < 1e-12);
    }

    #[test]
    fn equal_width_bin_of() {
        let e = BinEdges::equal_width(0.0, 10.0, 5);
        assert_eq!(e.bin_of(-1.0), 0);
        assert_eq!(e.bin_of(0.0), 0);
        assert_eq!(e.bin_of(3.9), 1);
        assert_eq!(e.bin_of(9.9), 4);
        assert_eq!(e.bin_of(10.0), 4);
        assert_eq!(e.bin_of(99.0), 4);
    }

    #[test]
    fn random_edges_sorted_and_cover() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let e = BinEdges::random(-2.0, 7.0, 8, &mut rng);
            assert_eq!(e.n_bins(), 8);
            for w in e.edges.windows(2) {
                assert!(w[0] <= w[1]);
            }
            for v in [-2.0f32, 0.0, 3.3, 6.999] {
                let b = e.bin_of(v);
                assert!(b < 8);
                assert!(e.edges[b] <= v || b == 0);
            }
        }
    }

    #[test]
    fn perfect_split_has_zero_impurity() {
        // Class 0 in bins 0-1, class 1 in bins 2-3: threshold after bin 1
        // separates perfectly.
        let mut h = ClassHistogram::new(BinEdges::equal_width(0.0, 4.0, 4), 2);
        let c = OpCounter::new();
        for _ in 0..10 {
            h.insert(0.5, 0, &c);
            h.insert(1.5, 0, &c);
            h.insert(2.5, 1, &c);
            h.insert(3.5, 1, &c);
        }
        assert_eq!(c.get(), 40);
        let scan = h.scan_thresholds(Impurity::Gini);
        assert_eq!(scan.len(), 3);
        let best = scan
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .unwrap();
        assert_eq!(best.0, 1, "perfect threshold after bin 1");
        assert!(best.1 .0.abs() < 1e-12, "impurity should be 0");
        // mixed thresholds are worse
        assert!(scan[0].0 > 0.1);
    }

    #[test]
    fn se_shrinks_with_n() {
        let c = OpCounter::new();
        let mut small = ClassHistogram::new(BinEdges::equal_width(0.0, 1.0, 4), 2);
        let mut large = ClassHistogram::new(BinEdges::equal_width(0.0, 1.0, 4), 2);
        let mut rng = Rng::new(9);
        for i in 0..40 {
            small.insert(rng.f32(), i % 2, &c);
        }
        let mut rng = Rng::new(9);
        for i in 0..4000 {
            large.insert(rng.f32(), i % 2, &c);
        }
        let s = small.scan_thresholds(Impurity::Gini)[1].1;
        let l = large.scan_thresholds(Impurity::Gini)[1].1;
        assert!(l < s, "SE must shrink with n: {s} -> {l}");
        assert!(l < 0.05);
    }

    #[test]
    fn i8_fill_is_bit_identical_to_decode_then_fill() {
        // Digest neutrality of the integer-domain MABSplit scan: both the
        // LUT branch (≥256 codes) and the short-run branch must land every
        // code in the same bin as decode-to-f32 + fill.
        let h = crate::kernels::quant::I8Header { min: -1.25, scale: 0.02 };
        for n in [7usize, 300] {
            let codes: Vec<u8> = (0..n).map(|i| ((i * 37) % 256) as u8).collect();
            let vals: Vec<f32> = codes.iter().map(|&u| h.decode(u)).collect();
            let edges = BinEdges::equal_width(-1.5, 4.5, 10);
            let (ca, cb) = (OpCounter::new(), OpCounter::new());
            let mut a = ClassHistogram::new(edges.clone(), 3);
            let mut b = ClassHistogram::new(edges.clone(), 3);
            a.fill(&vals, (0..n).map(|i| i % 3), &ca);
            b.fill_i8(&h, &codes, (0..n).map(|i| i % 3), &cb);
            assert_eq!(ca.get(), cb.get(), "n={n}: insertion counts");
            assert_eq!(a.counts, b.counts, "n={n}: class bins diverged");
            let mut am = MomentHistogram::new(edges.clone());
            let mut bm = MomentHistogram::new(edges);
            am.fill(&vals, (0..n).map(|i| i as f64 * 0.5), &ca);
            bm.fill_i8(&h, &codes, (0..n).map(|i| i as f64 * 0.5), &cb);
            for (x, y) in am.moments.iter().zip(&bm.moments) {
                assert_eq!(x.0.to_bits(), y.0.to_bits(), "n={n}");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "n={n}");
                assert_eq!(x.2.to_bits(), y.2.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn mse_scan_finds_step_function() {
        // y = 0 below 0.5, y = 10 above: best threshold in the middle.
        let c = OpCounter::new();
        let mut h = MomentHistogram::new(BinEdges::equal_width(0.0, 1.0, 10));
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let x = rng.f32();
            let y = if x < 0.5 { 0.0 } else { 10.0 };
            h.insert(x, y, &c);
        }
        let scan = h.scan_thresholds();
        let best = scan
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .unwrap();
        assert_eq!(best.0, 4, "threshold after bin 4 (= x < 0.5)");
        assert!(best.1 .0 < 0.1);
    }

    #[test]
    fn entropy_scan_matches_gini_ranking_roughly() {
        let c = OpCounter::new();
        let mut h = ClassHistogram::new(BinEdges::equal_width(0.0, 1.0, 6), 3);
        let mut rng = Rng::new(11);
        for _ in 0..600 {
            let x = rng.f32();
            let class = if x < 0.33 { 0 } else if x < 0.66 { 1 } else { 2 };
            h.insert(x, class, &c);
        }
        let g = h.scan_thresholds(Impurity::Gini);
        let e = h.scan_thresholds(Impurity::Entropy);
        let argmin = |v: &Vec<(f64, f64)>| {
            v.iter()
                .enumerate()
                .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
                .unwrap()
                .0
        };
        // Both should pick a boundary threshold (bin edge near .33 or .66).
        let bg = argmin(&g);
        let be = argmin(&e);
        assert!(bg == 1 || bg == 3, "gini picked {bg}");
        assert!(be == 1 || be == 3, "entropy picked {be}");
    }
}
