//! A histogram decision tree with pluggable node-splitting solver
//! (exact or MABSplit) — the single-tree substrate for every Chapter 3
//! model (RF / ExtraTrees / Random Patches are ensembles of these).

use crate::data::LabeledDataset;
use crate::forest::histogram::{gini, Impurity};
use crate::forest::split::{
    make_edges, solve_exactly, solve_mab_threaded, Split, SplitContext, TrainSet,
};
use crate::metrics::OpCounter;
use crate::store::DatasetView;
use crate::util::rng::Rng;

/// Which node-splitting subroutine to use (the ONLY difference between a
/// baseline model and its +MABSplit variant — §3.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    Exact,
    MabSplit { batch_size: usize, delta_x1000: u32 },
}

impl Solver {
    pub fn mab() -> Self {
        Solver::MabSplit { batch_size: 100, delta_x1000: 10 } // δ = 0.01
    }

    fn delta(&self) -> f64 {
        match self {
            Solver::Exact => 0.0,
            Solver::MabSplit { delta_x1000, .. } => *delta_x1000 as f64 / 1000.0,
        }
    }
}

/// Tree-level hyperparameters.
#[derive(Clone, Debug)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Minimum impurity decrease required to split (paper: 0.005).
    pub min_impurity_decrease: f64,
    /// Number of histogram bins T per feature.
    pub t_bins: usize,
    /// Features sampled per node (√M for RF classification).
    pub features_per_node: usize,
    /// ExtraTrees-style random (non-equal-width) bin edges.
    pub random_edges: bool,
    pub solver: Solver,
    pub impurity: Impurity,
    /// Shard-parallel MABSplit observation (see
    /// [`crate::bandit::BanditConfig::threads`]); 1 = sequential.
    pub threads: usize,
}

/// One tree node.
#[derive(Clone, Debug)]
pub enum Node {
    Leaf {
        /// Class probabilities (classification) or [mean] (regression).
        value: Vec<f32>,
        n: usize,
    },
    Internal {
        feature: usize,
        threshold: f32,
        /// Impurity decrease achieved (for MDI importances).
        gain: f64,
        n: usize,
        left: Box<Node>,
        right: Box<Node>,
    },
}

/// A trained decision tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    pub root: Node,
    pub n_classes: usize,
    pub nodes_split: usize,
}

/// A shared, optional insertion budget (Tables 3.3–3.5). `None` = unlimited.
pub struct Budget<'a> {
    pub counter: &'a OpCounter,
    pub limit: Option<u64>,
}

impl<'a> Budget<'a> {
    pub fn remaining(&self) -> u64 {
        match self.limit {
            None => u64::MAX,
            Some(l) => l.saturating_sub(self.counter.get()),
        }
    }
}

impl DecisionTree {
    /// Fit a tree on `rows` of `ds`. `ranges` are global per-feature
    /// (min,max); the budget is shared across the whole forest.
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        ds: &LabeledDataset,
        rows: &[usize],
        cfg: &TreeConfig,
        ranges: &[(f32, f32)],
        budget: &Budget,
        feature_pool: &[usize],
        rng: &mut Rng,
    ) -> DecisionTree {
        Self::fit_view(&TrainSet::of(ds), rows, cfg, ranges, budget, feature_pool, rng)
    }

    /// [`DecisionTree::fit`] over any [`crate::store::DatasetView`]-backed
    /// [`TrainSet`] — the columnar / out-of-core training path.
    #[allow(clippy::too_many_arguments)]
    pub fn fit_view(
        ts: &TrainSet,
        rows: &[usize],
        cfg: &TreeConfig,
        ranges: &[(f32, f32)],
        budget: &Budget,
        feature_pool: &[usize],
        rng: &mut Rng,
    ) -> DecisionTree {
        let mut nodes_split = 0usize;
        let root =
            build_node(ts, rows, cfg, ranges, budget, feature_pool, rng, 0, &mut nodes_split);
        DecisionTree { root, n_classes: ts.n_classes, nodes_split }
    }

    /// Per-example prediction: class-probability vector or [mean].
    pub fn predict_row(&self, x: &[f32]) -> &[f32] {
        let mut node = &self.root;
        loop {
            match node {
                Node::Leaf { value, .. } => return value,
                Node::Internal { feature, threshold, left, right, .. } => {
                    node = if x[*feature] < *threshold { left } else { right };
                }
            }
        }
    }

    /// Absorb one labeled example into the trained tree without changing
    /// its structure: route it to a leaf and fold it into every node's
    /// count and the leaf's value (class distribution / running mean) —
    /// the per-tree building block of [`super::ensemble::Forest::refresh`].
    /// Costs one histogram-insertion on `counter` (the same budget metric
    /// training pays per point per feature).
    pub fn absorb_row(&mut self, x: &[f32], y: f32, counter: &OpCounter) {
        counter.incr();
        let regression = self.n_classes == 0;
        let mut node = &mut self.root;
        loop {
            match node {
                Node::Leaf { value, n } => {
                    let prev = *n as f32;
                    if regression {
                        value[0] = (value[0] * prev + y) / (prev + 1.0);
                    } else {
                        // Convert probabilities back to counts, add, renormalize.
                        for (c, p) in value.iter_mut().enumerate() {
                            let mut count = *p * prev;
                            if c == y as usize {
                                count += 1.0;
                            }
                            *p = count / (prev + 1.0);
                        }
                    }
                    *n += 1;
                    return;
                }
                Node::Internal { feature, threshold, n, left, right, .. } => {
                    *n += 1;
                    node = if x[*feature] < *threshold { left } else { right };
                }
            }
        }
    }

    /// Accumulate impurity-decrease MDI contributions into `acc`.
    pub fn accumulate_mdi(&self, acc: &mut [f64]) {
        fn walk(node: &Node, acc: &mut [f64], n_root: f64) {
            if let Node::Internal { feature, gain, n, left, right, .. } = node {
                acc[*feature] += gain * (*n as f64) / n_root;
                walk(left, acc, n_root);
                walk(right, acc, n_root);
            }
        }
        let n_root = match &self.root {
            Node::Leaf { n, .. } => *n as f64,
            Node::Internal { n, .. } => *n as f64,
        };
        walk(&self.root, acc, n_root.max(1.0));
    }
}

fn leaf_value(ts: &TrainSet, rows: &[usize]) -> Vec<f32> {
    if ts.is_regression() {
        let mean = if rows.is_empty() {
            0.0
        } else {
            rows.iter().map(|&r| ts.y[r] as f64).sum::<f64>() / rows.len() as f64
        };
        vec![mean as f32]
    } else {
        let mut probs = vec![0f32; ts.n_classes];
        for &r in rows {
            probs[ts.y[r] as usize] += 1.0;
        }
        let total: f32 = probs.iter().sum();
        if total > 0.0 {
            probs.iter_mut().for_each(|p| *p /= total);
        }
        probs
    }
}

fn node_impurity(ts: &TrainSet, rows: &[usize], imp: Impurity) -> f64 {
    if ts.is_regression() {
        let n = rows.len() as f64;
        if n == 0.0 {
            return 0.0;
        }
        let s: f64 = rows.iter().map(|&r| ts.y[r] as f64).sum();
        let q: f64 = rows.iter().map(|&r| (ts.y[r] as f64).powi(2)).sum();
        (q / n - (s / n) * (s / n)).max(0.0)
    } else {
        let mut counts = vec![0f64; ts.n_classes];
        for &r in rows {
            counts[ts.y[r] as usize] += 1.0;
        }
        match imp {
            Impurity::Gini => gini(&counts, rows.len() as f64),
            Impurity::Entropy => crate::forest::histogram::entropy(&counts, rows.len() as f64),
            Impurity::Mse => unreachable!(),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn build_node(
    ts: &TrainSet,
    rows: &[usize],
    cfg: &TreeConfig,
    ranges: &[(f32, f32)],
    budget: &Budget,
    feature_pool: &[usize],
    rng: &mut Rng,
    depth: usize,
    nodes_split: &mut usize,
) -> Node {
    let n = rows.len();
    let make_leaf = |rows: &[usize]| Node::Leaf { value: leaf_value(ts, rows), n: rows.len() };

    if depth >= cfg.max_depth || n < cfg.min_samples_split {
        return make_leaf(rows);
    }
    let parent_imp = node_impurity(ts, rows, cfg.impurity);
    if parent_imp <= 1e-12 {
        return make_leaf(rows); // pure node
    }
    // Budget check: a split needs at least ~n·m more insertions for the
    // exact solver / at least one batch for MABSplit.
    let m = cfg.features_per_node.min(feature_pool.len()).max(1);
    let needed = match cfg.solver {
        Solver::Exact => (n * m) as u64,
        Solver::MabSplit { batch_size, .. } => (batch_size * m) as u64,
    };
    if budget.remaining() < needed {
        return make_leaf(rows);
    }

    // Feature subsample for this node.
    let chosen = rng.sample_without_replacement(feature_pool.len(), m);
    let features: Vec<usize> = chosen.iter().map(|&i| feature_pool[i]).collect();
    let edges = make_edges(&features, ranges, cfg.t_bins, cfg.random_edges, rng);
    let ctx = SplitContext {
        ds: *ts,
        rows,
        features: &features,
        edges,
        impurity: cfg.impurity,
        counter: budget.counter,
    };
    let split: Option<Split> = match cfg.solver {
        Solver::Exact => solve_exactly(&ctx),
        Solver::MabSplit { batch_size, .. } => {
            // Small-node crossover (Fig B.4): below a few batches of data
            // the adaptive machinery costs more wall-clock than it saves —
            // fall back to the exact scan (identical output).
            if n < 4 * batch_size {
                solve_exactly(&ctx)
            } else {
                let delta = cfg.solver.delta();
                solve_mab_threaded(&ctx, batch_size, delta, rng.next_u64(), cfg.threads)
            }
        }
    };
    let Some(split) = split else { return make_leaf(rows) };
    let gain = parent_imp - split.child_impurity;
    if gain < cfg.min_impurity_decrease {
        return make_leaf(rows);
    }

    // Route rows by one column gather (order-preserving, so the child row
    // sets match the dense path exactly).
    let mut vals = vec![0f32; rows.len()];
    ts.x.read_col(split.feature, rows, &mut vals);
    let mut left_rows = Vec::new();
    let mut right_rows = Vec::new();
    for (&r, &v) in rows.iter().zip(&vals) {
        if v < split.threshold {
            left_rows.push(r);
        } else {
            right_rows.push(r);
        }
    }
    if left_rows.is_empty() || right_rows.is_empty() {
        return make_leaf(rows);
    }
    *nodes_split += 1;
    let left =
        build_node(ts, &left_rows, cfg, ranges, budget, feature_pool, rng, depth + 1, nodes_split);
    let right = build_node(
        ts,
        &right_rows,
        cfg,
        ranges,
        budget,
        feature_pool,
        rng,
        depth + 1,
        nodes_split,
    );
    Node::Internal {
        feature: split.feature,
        threshold: split.threshold,
        gain,
        n,
        left: Box::new(left),
        right: Box::new(right),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tabular::{make_classification, make_regression};
    use crate::forest::split::feature_ranges;

    fn cfg(solver: Solver, regression: bool) -> TreeConfig {
        TreeConfig {
            max_depth: 6,
            min_samples_split: 4,
            min_impurity_decrease: 0.005,
            t_bins: 10,
            features_per_node: 8,
            random_edges: false,
            solver,
            impurity: if regression { Impurity::Mse } else { Impurity::Gini },
            threads: 1,
        }
    }

    fn accuracy(tree: &DecisionTree, ds: &LabeledDataset) -> f64 {
        let mut correct = 0;
        for i in 0..ds.x.n {
            let probs = tree.predict_row(ds.x.row(i));
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == ds.y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / ds.x.n as f64
    }

    #[test]
    fn tree_learns_classification() {
        let ds = make_classification(1500, 8, 4, 2, 2.0, 21);
        let (train, test) = ds.split(0.25, 1);
        let rows: Vec<usize> = (0..train.x.n).collect();
        let pool: Vec<usize> = (0..train.x.d).collect();
        let ranges = feature_ranges(&train);
        let c = OpCounter::new();
        let b = Budget { counter: &c, limit: None };
        let mut rng = Rng::new(7);
        let tree = DecisionTree::fit(
            &train,
            &rows,
            &cfg(Solver::Exact, false),
            &ranges,
            &b,
            &pool,
            &mut rng,
        );
        let acc = accuracy(&tree, &test);
        assert!(acc > 0.8, "exact-tree accuracy {acc}");
    }

    #[test]
    fn mab_tree_matches_exact_accuracy() {
        let ds = make_classification(4000, 10, 4, 2, 2.0, 22);
        let (train, test) = ds.split(0.25, 2);
        let rows: Vec<usize> = (0..train.x.n).collect();
        let pool: Vec<usize> = (0..train.x.d).collect();
        let ranges = feature_ranges(&train);
        let mut accs = Vec::new();
        let mut insertions = Vec::new();
        for solver in [Solver::Exact, Solver::mab()] {
            let c = OpCounter::new();
            let b = Budget { counter: &c, limit: None };
            let mut rng = Rng::new(7);
            let tree =
                DecisionTree::fit(&train, &rows, &cfg(solver, false), &ranges, &b, &pool, &mut rng);
            accs.push(accuracy(&tree, &test));
            insertions.push(c.get());
        }
        assert!(accs[1] > accs[0] - 0.05, "mab {} vs exact {}", accs[1], accs[0]);
        assert!(
            insertions[1] < insertions[0],
            "MABSplit insertions {} ≥ exact {}",
            insertions[1],
            insertions[0]
        );
    }

    #[test]
    fn regression_tree_reduces_mse() {
        let ds = make_regression(2000, 6, 2, 1.0, 23);
        let (train, test) = ds.split(0.25, 3);
        let rows: Vec<usize> = (0..train.x.n).collect();
        let pool: Vec<usize> = (0..train.x.d).collect();
        let ranges = feature_ranges(&train);
        let c = OpCounter::new();
        let b = Budget { counter: &c, limit: None };
        let mut rng = Rng::new(9);
        let tree = DecisionTree::fit(
            &train,
            &rows,
            &cfg(Solver::mab(), true),
            &ranges,
            &b,
            &pool,
            &mut rng,
        );
        let mse: f64 = (0..test.x.n)
            .map(|i| {
                let p = tree.predict_row(test.x.row(i))[0] as f64;
                (p - test.y[i] as f64).powi(2)
            })
            .sum::<f64>()
            / test.x.n as f64;
        let var: f64 = {
            let ys: Vec<f64> = test.y.iter().map(|&v| v as f64).collect();
            let m = crate::util::stats::mean(&ys);
            ys.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / ys.len() as f64
        };
        assert!(mse < 0.7 * var, "tree mse {mse} vs label var {var}");
    }

    #[test]
    fn budget_limits_splits() {
        let ds = make_classification(2000, 8, 4, 2, 2.0, 24);
        let rows: Vec<usize> = (0..ds.x.n).collect();
        let pool: Vec<usize> = (0..ds.x.d).collect();
        let ranges = feature_ranges(&ds);
        let c = OpCounter::new();
        let b = Budget { counter: &c, limit: Some(2000 * 8) }; // one exact split's worth
        let mut rng = Rng::new(5);
        let tree = DecisionTree::fit(
            &ds,
            &rows,
            &cfg(Solver::Exact, false),
            &ranges,
            &b,
            &pool,
            &mut rng,
        );
        assert!(tree.nodes_split <= 1, "budget must stop after ~1 exact split");
        assert!(c.get() <= 2000 * 8 + 1);
    }

    #[test]
    fn absorb_row_updates_leaf_and_path_counts() {
        let ds = make_classification(800, 6, 3, 2, 2.5, 27);
        let rows: Vec<usize> = (0..ds.x.n).collect();
        let pool: Vec<usize> = (0..ds.x.d).collect();
        let ranges = feature_ranges(&ds);
        let c = OpCounter::new();
        let b = Budget { counter: &c, limit: None };
        let mut rng = Rng::new(3);
        let mut tree =
            DecisionTree::fit(&ds, &rows, &cfg(Solver::Exact, false), &ranges, &b, &pool, &mut rng);
        let root_n_before = match &tree.root {
            Node::Internal { n, .. } | Node::Leaf { n, .. } => *n,
        };
        let x = ds.x.row(0).to_vec();
        let y = ds.y[0];
        let before = tree.predict_row(&x)[y as usize];
        let cc = OpCounter::new();
        for _ in 0..50 {
            tree.absorb_row(&x, y, &cc);
        }
        assert_eq!(cc.get(), 50);
        let after = tree.predict_row(&x)[y as usize];
        assert!(after >= before, "absorbing label {y} must not lower its probability");
        assert!(after > 0.9, "50 repeats dominate the leaf: {after}");
        let root_n_after = match &tree.root {
            Node::Internal { n, .. } | Node::Leaf { n, .. } => *n,
        };
        assert_eq!(root_n_after, root_n_before + 50);
        // Leaf probabilities stay normalized.
        let probs = tree.predict_row(&x);
        let total: f32 = probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-4, "probs sum {total}");
    }

    #[test]
    fn mdi_flags_informative_features() {
        let ds = make_classification(3000, 10, 2, 2, 3.0, 25);
        let rows: Vec<usize> = (0..ds.x.n).collect();
        let pool: Vec<usize> = (0..ds.x.d).collect();
        let ranges = feature_ranges(&ds);
        let c = OpCounter::new();
        let b = Budget { counter: &c, limit: None };
        let mut rng = Rng::new(11);
        let tree = DecisionTree::fit(
            &ds,
            &rows,
            &cfg(Solver::Exact, false),
            &ranges,
            &b,
            &pool,
            &mut rng,
        );
        let mut mdi = vec![0f64; ds.x.d];
        tree.accumulate_mdi(&mut mdi);
        // The top-importance feature should be one that the tree actually
        // split on with real gain.
        let top = mdi
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assert!(*top.1 > 0.0);
    }
}
