//! Chapter 3 — faster forest training via MABSplit.
//!
//! * [`histogram`] — binned feature statistics + Gini/entropy/MSE
//!   impurities with delta-method confidence intervals (§3.3.1, §B.3);
//! * [`split`] — the exact brute-force node splitter and MABSplit
//!   (Algorithm 3) on the shared bandit engine;
//! * [`tree`] — a histogram decision tree parameterized by solver;
//! * [`ensemble`] — Random Forest / ExtraTrees / Random Patches, with
//!   optional fixed insertion budgets (Tables 3.3–3.4);
//! * [`importance`] — MDI + permutation importances and the top-k
//!   feature-stability score (Table 3.5).
//!
//! The *only* difference between a baseline model and its "+ MABSplit"
//! variant is the node-splitting subroutine — exactly the paper's
//! experimental control (§3.5).

pub mod ensemble;
pub mod histogram;
pub mod importance;
pub mod split;
pub mod tree;

pub use ensemble::{Forest, ForestConfig, ForestKind};
pub use histogram::Impurity;
pub use split::{
    refresh_split, solve_exact_cached, solve_exactly, solve_mab, solve_mab_threaded, Split,
    SplitCache, SplitContext, TrainSet,
};
pub use tree::{DecisionTree, Solver, TreeConfig};
