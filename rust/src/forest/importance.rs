//! Feature-importance computation and stability scoring (Table 3.5).
//!
//! Two importance models, as in the paper: Mean Decrease in Impurity
//! (MDI, accumulated during training) and Permutation Feature Importance
//! (accuracy/MSE drop when a feature column is shuffled on held-out
//! data). Stability is measured across independently-trained forests as
//! the mean pairwise overlap of their top-k feature sets — the standard
//! stability index the paper cites [130].

use crate::data::LabeledDataset;
use crate::forest::ensemble::{Forest, ForestConfig};
use crate::metrics::OpCounter;
use crate::util::rng::Rng;

/// Permutation importance of every feature on `eval` data: the drop in
/// accuracy (classification) / rise in MSE (regression) when that
/// feature's column is shuffled. `repeats` shuffles are averaged.
pub fn permutation_importance(
    forest: &Forest,
    eval: &LabeledDataset,
    repeats: usize,
    seed: u64,
) -> Vec<f64> {
    let base = score(forest, eval);
    let mut rng = Rng::new(seed);
    let mut imp = vec![0f64; eval.x.d];
    for f in 0..eval.x.d {
        let mut total = 0.0;
        for _ in 0..repeats {
            let mut shuffled = eval.clone();
            // Shuffle column f.
            let mut col: Vec<f32> = (0..eval.x.n).map(|i| eval.x.row(i)[f]).collect();
            rng.shuffle(&mut col);
            for i in 0..eval.x.n {
                shuffled.x.row_mut(i)[f] = col[i];
            }
            total += base - score(forest, &shuffled);
        }
        imp[f] = total / repeats as f64;
    }
    imp
}

/// Higher-is-better score: accuracy for classification, −MSE for
/// regression.
fn score(forest: &Forest, ds: &LabeledDataset) -> f64 {
    if ds.is_regression() {
        -forest.mse(ds)
    } else {
        forest.accuracy(ds)
    }
}

/// Indices of the top-k features by importance. Features with
/// non-positive importance are excluded — padding the set with
/// deterministic zero-importance ties would fake stability.
pub fn top_k(importances: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..importances.len()).collect();
    idx.sort_by(|&a, &b| importances[b].partial_cmp(&importances[a]).unwrap());
    idx.truncate(k);
    idx.retain(|&i| importances[i] > 0.0);
    idx
}

/// Mean pairwise stability of top-k feature sets across runs: the
/// Kuncheva-style consistency index reduces to average overlap fraction
/// corrected for chance; we report the widely-used mean Jaccard overlap.
pub fn stability(top_sets: &[Vec<usize>]) -> f64 {
    if top_sets.len() < 2 {
        return 1.0;
    }
    let mut total = 0.0;
    let mut pairs = 0usize;
    for i in 0..top_sets.len() {
        for j in (i + 1)..top_sets.len() {
            let a: std::collections::HashSet<_> = top_sets[i].iter().collect();
            let b: std::collections::HashSet<_> = top_sets[j].iter().collect();
            let inter = a.intersection(&b).count() as f64;
            let union = a.union(&b).count() as f64;
            total += if union > 0.0 { inter / union } else { 1.0 };
            pairs += 1;
        }
    }
    total / pairs as f64
}

/// Importance-computation mode for the stability experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImportanceKind {
    Mdi,
    Permutation,
}

/// Train `runs` forests (different seeds) under the configured budget and
/// return the stability of their top-k feature selections.
pub fn stability_experiment(
    ds: &LabeledDataset,
    base_cfg: &ForestConfig,
    kind: ImportanceKind,
    k: usize,
    runs: usize,
) -> f64 {
    let (train, eval) = ds.split(0.25, base_cfg.seed ^ 0xFEA7);
    let mut tops = Vec::new();
    for run in 0..runs {
        let mut cfg = base_cfg.clone();
        cfg.seed = base_cfg.seed.wrapping_add(1_000_003 * run as u64 + 17);
        let c = OpCounter::new();
        let f = Forest::fit(&train, &cfg, &c);
        let imp = match kind {
            ImportanceKind::Mdi => f.mdi_importances(train.x.d),
            ImportanceKind::Permutation => permutation_importance(&f, &eval, 2, cfg.seed),
        };
        tops.push(top_k(&imp, k));
    }
    stability(&tops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tabular::make_classification;
    use crate::forest::ensemble::ForestKind;
    use crate::forest::tree::Solver;

    #[test]
    fn top_k_orders_correctly() {
        let imp = [0.1, 0.5, 0.0, 0.4];
        assert_eq!(top_k(&imp, 2), vec![1, 3]);
    }

    #[test]
    fn stability_extremes() {
        let same = vec![vec![0, 1, 2], vec![0, 1, 2], vec![2, 1, 0]];
        assert!((stability(&same) - 1.0).abs() < 1e-12);
        let disjoint = vec![vec![0, 1], vec![2, 3]];
        assert_eq!(stability(&disjoint), 0.0);
    }

    #[test]
    fn permutation_importance_finds_signal() {
        let ds = make_classification(2000, 8, 2, 2, 3.0, 41);
        let (train, eval) = ds.split(0.3, 1);
        let c = OpCounter::new();
        let mut cfg = ForestConfig::new(ForestKind::RandomForest, Solver::Exact);
        cfg.n_trees = 6;
        cfg.max_depth = 4;
        let f = Forest::fit(&train, &cfg, &c);
        let imp = permutation_importance(&f, &eval, 3, 7);
        // the max-importance feature must carry genuinely positive signal
        let best = imp.iter().cloned().fold(f64::MIN, f64::max);
        assert!(best > 0.01, "no feature shows permutation signal: {imp:?}");
    }

    #[test]
    fn stability_pipeline_recovers_informative_features() {
        // Functional check of the Table 3.5 pipeline: with an ample budget
        // and several trees, MDI top-k selection over k = n_informative
        // features is reasonably stable across seeds. (The quantitative
        // exact-vs-MABSplit comparison under tight budgets is an
        // experiment — `repro exp tab3.5` — not a unit test.)
        let ds = make_classification(3000, 12, 3, 2, 3.0, 43);
        let mut cfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
        cfg.n_trees = 10;
        cfg.max_depth = 4;
        let s = stability_experiment(&ds, &cfg, ImportanceKind::Mdi, 3, 3);
        assert!((0.0..=1.0).contains(&s));
        assert!(s > 0.4, "MDI stability unexpectedly low: {s}");
    }
}
