//! Forest ensembles: Random Forest, ExtraTrees, Random Patches — each
//! trainable with the exact node-splitter or MABSplit (Tables 3.1–3.4),
//! with optional shared insertion budgets (the fixed-budget experiments).

use crate::data::LabeledDataset;
use crate::forest::histogram::Impurity;
use crate::forest::split::{feature_ranges_view, TrainSet};
use crate::forest::tree::{Budget, DecisionTree, Solver, TreeConfig};
use crate::metrics::OpCounter;
use crate::store::DatasetView;
use crate::util::rng::Rng;

/// Which ensemble variant (§3.5 "Baseline Models").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForestKind {
    /// Bootstrap rows; √M features per node split.
    RandomForest,
    /// Like RF but random histogram bin edges; in regression all features
    /// are considered at each split.
    ExtraTrees,
    /// One fixed row/feature subsample (α_n, α_f) for the whole forest.
    RandomPatches,
}

/// Forest configuration.
#[derive(Clone, Debug)]
pub struct ForestConfig {
    pub kind: ForestKind,
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_impurity_decrease: f64,
    pub t_bins: usize,
    pub solver: Solver,
    pub impurity: Impurity,
    /// Random Patches fractions.
    pub alpha_n: f64,
    pub alpha_f: f64,
    /// Insertion budget for the fixed-budget experiments (None = off).
    pub budget: Option<u64>,
    pub seed: u64,
    /// Shard-parallel MABSplit observation (see
    /// [`crate::bandit::BanditConfig::threads`]); 1 = sequential.
    pub threads: usize,
}

impl ForestConfig {
    pub fn new(kind: ForestKind, solver: Solver) -> Self {
        ForestConfig {
            kind,
            n_trees: 5,
            max_depth: 5,
            min_impurity_decrease: 0.005,
            t_bins: 10,
            solver,
            impurity: Impurity::Gini,
            alpha_n: 0.7,
            alpha_f: 0.85,
            budget: None,
            seed: 42,
            threads: 1,
        }
    }
}

/// A trained forest.
pub struct Forest {
    pub trees: Vec<DecisionTree>,
    pub n_classes: usize,
    /// Histogram insertions consumed during training.
    pub insertions: u64,
    /// Trees that completed training before the budget ran out.
    pub completed_trees: usize,
}

impl Forest {
    /// Train a forest; `counter` records histogram insertions.
    pub fn fit(ds: &LabeledDataset, cfg: &ForestConfig, counter: &OpCounter) -> Forest {
        Self::fit_view(&TrainSet::of(ds), cfg, counter)
    }

    /// [`Forest::fit`] over any [`DatasetView`]-backed [`TrainSet`] — the
    /// columnar / out-of-core training path (histogram fills become
    /// column scans; see [`crate::store`]).
    pub fn fit_view(ts: &TrainSet, cfg: &ForestConfig, counter: &OpCounter) -> Forest {
        let before = counter.get();
        let mut rng = Rng::new(cfg.seed);
        let regression = ts.is_regression();
        let n_total = ts.x.n_rows();
        let m_total = ts.x.n_cols();

        // Random Patches: one fixed row/feature subsample for the forest.
        let (patch_rows, feature_pool): (Vec<usize>, Vec<usize>) = match cfg.kind {
            ForestKind::RandomPatches => {
                let nr = ((n_total as f64) * cfg.alpha_n).round().max(1.0) as usize;
                let nf = ((m_total as f64) * cfg.alpha_f).round().max(1.0) as usize;
                (
                    rng.sample_without_replacement(n_total, nr.min(n_total)),
                    rng.sample_without_replacement(m_total, nf.min(m_total)),
                )
            }
            _ => ((0..n_total).collect(), (0..m_total).collect()),
        };

        // Features per node: √M for classification; ExtraTrees regression
        // uses all features (§3.5).
        let m_node = if regression && cfg.kind == ForestKind::ExtraTrees {
            feature_pool.len()
        } else {
            ((feature_pool.len() as f64).sqrt().round() as usize).clamp(1, feature_pool.len())
        };

        let tree_cfg = TreeConfig {
            max_depth: cfg.max_depth,
            min_samples_split: 4,
            min_impurity_decrease: cfg.min_impurity_decrease,
            t_bins: if cfg.kind == ForestKind::ExtraTrees && !regression {
                ((m_total as f64).sqrt().round() as usize).max(2)
            } else {
                cfg.t_bins
            },
            features_per_node: m_node,
            random_edges: cfg.kind == ForestKind::ExtraTrees,
            solver: cfg.solver,
            impurity: if regression { Impurity::Mse } else { cfg.impurity },
            threads: cfg.threads,
        };
        let ranges = feature_ranges_view(ts.x);
        let budget = Budget { counter, limit: cfg.budget.map(|b| before + b) };

        let mut trees = Vec::new();
        let mut completed = 0usize;
        for t in 0..cfg.n_trees {
            if budget.remaining() == 0 {
                break;
            }
            let before_tree = budget.remaining();
            // Bootstrap sample (RF & ExtraTrees here both bootstrap rows;
            // Random Patches uses its fixed patch).
            let rows: Vec<usize> = match cfg.kind {
                ForestKind::RandomPatches => patch_rows.clone(),
                _ => (0..n_total).map(|_| rng.below(n_total)).collect(),
            };
            let mut trng = rng.fork(t as u64);
            let tree = DecisionTree::fit_view(
                ts,
                &rows,
                &tree_cfg,
                &ranges,
                &budget,
                &feature_pool,
                &mut trng,
            );
            // A tree "completed" if the budget didn't interrupt it: either
            // budget still has room, or the tree stopped for its own
            // reasons (we approximate: room remains for another split).
            let ran_out = budget.remaining() == 0 && before_tree > 0;
            if !ran_out {
                completed += 1;
            }
            let splits = tree.nodes_split;
            trees.push(tree);
            // Budget exhausted — or too depleted to afford even one split
            // (a zero-split tree under a budget): stop, don't spin out
            // stump-only trees forever.
            if budget.remaining() == 0 || (cfg.budget.is_some() && splits == 0) {
                break;
            }
        }

        Forest {
            trees,
            n_classes: ts.n_classes,
            insertions: counter.get() - before,
            completed_trees: completed,
        }
    }

    /// Warm-started refresh after an append: keep every tree's split
    /// structure and absorb the rows at `new_rows` into node counts and
    /// leaf statistics ([`DecisionTree::absorb_row`] — one insertion per
    /// row per tree on `counter`, against a cold refit's full training
    /// cost). Structural drift is the [`crate::forest::SplitCache`] /
    /// [`crate::forest::refresh_split`] path's job: callers that keep a
    /// root-split cache can detect a changed best split and escalate to a
    /// cold `fit_view` for exactly the trees that need it.
    pub fn refresh(&self, ts: &TrainSet, new_rows: &[usize], counter: &OpCounter) -> Forest {
        let before = counter.get();
        let mut trees = self.trees.clone();
        let mut x = vec![0f32; ts.x.n_cols()];
        for &r in new_rows {
            ts.x.read_row(r, &mut x);
            for t in trees.iter_mut() {
                t.absorb_row(&x, ts.y[r], counter);
            }
        }
        Forest {
            trees,
            n_classes: self.n_classes,
            insertions: counter.get() - before,
            completed_trees: self.completed_trees,
        }
    }

    /// Soft-vote class probabilities / mean prediction for one row.
    pub fn predict_row(&self, x: &[f32]) -> Vec<f32> {
        let width = if self.n_classes == 0 { 1 } else { self.n_classes };
        let mut acc = vec![0f32; width];
        if self.trees.is_empty() {
            return acc;
        }
        for t in &self.trees {
            let p = t.predict_row(x);
            for (a, &v) in acc.iter_mut().zip(p) {
                *a += v;
            }
        }
        let k = self.trees.len() as f32;
        acc.iter_mut().for_each(|a| *a /= k);
        acc
    }

    /// Classification accuracy on a dataset.
    pub fn accuracy(&self, ds: &LabeledDataset) -> f64 {
        self.accuracy_view(&TrainSet::of(ds))
    }

    /// Classification accuracy over any [`DatasetView`]-backed
    /// [`TrainSet`] (rows are gathered through the view).
    pub fn accuracy_view(&self, ts: &TrainSet) -> f64 {
        assert!(self.n_classes > 0);
        let n = ts.x.n_rows();
        let mut row = vec![0f32; ts.x.n_cols()];
        let mut correct = 0usize;
        for i in 0..n {
            ts.x.read_row(i, &mut row);
            let p = self.predict_row(&row);
            let pred = p
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c)
                .unwrap_or(0);
            if pred == ts.y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / n.max(1) as f64
    }

    /// Regression MSE on a dataset.
    pub fn mse(&self, ds: &LabeledDataset) -> f64 {
        assert_eq!(self.n_classes, 0);
        let mut s = 0.0;
        for i in 0..ds.x.n {
            let p = self.predict_row(ds.x.row(i))[0] as f64;
            let e = p - ds.y[i] as f64;
            s += e * e;
        }
        s / ds.x.n.max(1) as f64
    }

    /// Mean Decrease in Impurity feature importances, normalized to sum 1.
    pub fn mdi_importances(&self, n_features: usize) -> Vec<f64> {
        let mut acc = vec![0f64; n_features];
        for t in &self.trees {
            t.accumulate_mdi(&mut acc);
        }
        let total: f64 = acc.iter().sum();
        if total > 0.0 {
            acc.iter_mut().for_each(|a| *a /= total);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tabular::{make_classification, make_regression};

    #[test]
    fn rf_beats_single_tree_noise() {
        let ds = make_classification(2500, 12, 5, 3, 1.6, 31);
        let (train, test) = ds.split(0.3, 1);
        let c = OpCounter::new();
        let mut cfg = ForestConfig::new(ForestKind::RandomForest, Solver::Exact);
        cfg.n_trees = 8;
        let f = Forest::fit(&train, &cfg, &c);
        let acc = f.accuracy(&test);
        assert!(acc > 0.6, "forest accuracy {acc}");
        assert!(f.insertions > 0);
    }

    #[test]
    fn mabsplit_forest_similar_accuracy_fewer_insertions() {
        let ds = make_classification(6000, 16, 5, 2, 2.0, 32);
        let (train, test) = ds.split(0.25, 2);
        let mut results = Vec::new();
        for solver in [Solver::Exact, Solver::mab()] {
            let c = OpCounter::new();
            let mut cfg = ForestConfig::new(ForestKind::RandomForest, solver);
            cfg.n_trees = 4;
            let f = Forest::fit(&train, &cfg, &c);
            results.push((f.accuracy(&test), c.get()));
        }
        let (acc_e, ins_e) = results[0];
        let (acc_m, ins_m) = results[1];
        assert!(acc_m > acc_e - 0.05, "mab acc {acc_m} vs exact {acc_e}");
        assert!(ins_m < ins_e, "mab insertions {ins_m} ≥ exact {ins_e}");
    }

    #[test]
    fn parallel_forest_bit_identical() {
        // Forest-level determinism across the threaded MABSplit path:
        // identical insertion totals and identical per-tree structure.
        let ds = make_classification(3_000, 12, 4, 2, 2.0, 33);
        let run = |threads: usize| {
            let c = OpCounter::new();
            let mut cfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
            cfg.n_trees = 3;
            cfg.threads = threads;
            let f = Forest::fit(&ds, &cfg, &c);
            let splits: Vec<usize> = f.trees.iter().map(|t| t.nodes_split).collect();
            (c.get(), splits, f.accuracy(&ds).to_bits())
        };
        let seq = run(1);
        assert_eq!(run(4), seq);
    }

    #[test]
    fn all_kinds_train_classification_and_regression() {
        let dsc = make_classification(800, 10, 4, 2, 2.0, 33);
        let dsr = make_regression(800, 8, 3, 0.5, 34);
        for kind in [ForestKind::RandomForest, ForestKind::ExtraTrees, ForestKind::RandomPatches] {
            for solver in [Solver::Exact, Solver::mab()] {
                let c = OpCounter::new();
                let mut cfg = ForestConfig::new(kind, solver);
                cfg.n_trees = 2;
                let f = Forest::fit(&dsc, &cfg, &c);
                assert!(!f.trees.is_empty(), "{kind:?} classification");
                let acc = f.accuracy(&dsc);
                assert!(acc > 0.5, "{kind:?}/{solver:?} acc {acc}");

                let c = OpCounter::new();
                let f = Forest::fit(&dsr, &cfg, &c);
                assert!(!f.trees.is_empty(), "{kind:?} regression");
                let _ = f.mse(&dsr);
            }
        }
    }

    #[test]
    fn refresh_absorbs_appends_at_a_fraction_of_a_cold_refit() {
        use crate::util::testkit;
        let fx = testkit::refresh_corpus()
            .into_iter()
            .find(|f| f.name == "medium-clusterable")
            .unwrap();
        let full = fx.full();
        let mut cfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
        cfg.n_trees = 4;

        let c_prev = OpCounter::new();
        let prev = Forest::fit(&fx.base, &cfg, &c_prev);

        let c_cold = OpCounter::new();
        let cold = Forest::fit(&full, &cfg, &c_cold);

        let new_rows: Vec<usize> = (fx.base.x.n..full.x.n).collect();
        let c_warm = OpCounter::new();
        let warm = prev.refresh(&TrainSet::of(&full), &new_rows, &c_warm);
        assert_eq!(warm.insertions, (new_rows.len() * 4) as u64);
        assert!(
            c_warm.get() * 2 < c_cold.get(),
            "warm {} vs cold {}",
            c_warm.get(),
            c_cold.get()
        );
        // Structure kept, statistics current: accuracy on the grown data
        // stays within noise of the cold refit.
        let acc_warm = warm.accuracy(&full);
        let acc_cold = cold.accuracy(&full);
        assert!(
            acc_warm > acc_cold - 0.05,
            "warm acc {acc_warm} vs cold {acc_cold}"
        );
        // Root counts reflect the absorbed rows.
        let n_root: usize = match &warm.trees[0].root {
            crate::forest::tree::Node::Internal { n, .. }
            | crate::forest::tree::Node::Leaf { n, .. } => *n,
        };
        assert_eq!(n_root, fx.base.x.n + new_rows.len());
    }

    #[test]
    fn fixed_budget_mabsplit_trains_more_trees() {
        // Table 3.3's mechanism: same insertion budget, more trees.
        let ds = make_classification(8000, 16, 10, 2, 2.0, 35);
        let budget = 8_000 * 8; // two exact √16=4-feature root splits' worth
        let count_trees = |solver: Solver| {
            let c = OpCounter::new();
            let mut cfg = ForestConfig::new(ForestKind::RandomForest, solver);
            cfg.n_trees = 50;
            cfg.budget = Some(budget as u64);
            let f = Forest::fit(&ds, &cfg, &c);
            // The budget is checked before each split and spent during it,
            // so the overshoot is bounded by one node's full scan (n·m) —
            // the same semantics as the paper's implementation.
            assert!(
                c.get() <= budget as u64 + (8000 * 4) as u64,
                "budget exceeded: {}",
                c.get()
            );
            f.trees.iter().map(|t| t.nodes_split).sum::<usize>()
        };
        let exact_splits = count_trees(Solver::Exact);
        let mab_splits = count_trees(Solver::mab());
        assert!(
            mab_splits > exact_splits,
            "MABSplit should afford more splits: {mab_splits} vs {exact_splits}"
        );
    }

    #[test]
    fn empty_budget_yields_no_splits() {
        let ds = make_classification(500, 8, 3, 2, 2.0, 36);
        let c = OpCounter::new();
        let mut cfg = ForestConfig::new(ForestKind::RandomForest, Solver::Exact);
        cfg.budget = Some(0);
        let f = Forest::fit(&ds, &cfg, &c);
        assert_eq!(c.get(), 0);
        let total_splits: usize = f.trees.iter().map(|t| t.nodes_split).sum();
        assert_eq!(total_splits, 0);
    }
}
