//! Node-splitting solvers: the exact (brute-force histogram) solver and
//! MABSplit (Algorithm 3) — the paper's contribution.
//!
//! Both answer the same question (Eq. 3.1/3.3): over the node's feature
//! subset and each feature's thresholds, which (f, t) minimizes the
//! weighted child impurity? The exact solver inserts *every* node point
//! into every feature histogram (O(n·m) insertions); MABSplit treats each
//! (f, t) as an arm and inserts points batch-by-batch, eliminating
//! hopeless splits early — O(1) in n when split gaps don't shrink with n.
//!
//! Data access goes through [`DatasetView`] (a [`TrainSet`] bundles the
//! feature view with labels): each feature's histogram fill is one
//! chunk-aligned [`DatasetView::for_each_col_block`] sweep — a true
//! column scan on a [`crate::store::ColumnStore`] whose quantized chunks
//! are decoded element-fused into arena scratch (no full-chunk
//! `Vec<f32>`), instead of the row-major striding the dense path forced
//! — and values are inserted in batch order, so the accumulated moments
//! are bit-identical to the legacy `Matrix` path.

use crate::bandit::{successive_elimination, AdaptiveArms, BanditConfig, ParCtx, Sampling};
use crate::data::LabeledDataset;
use crate::forest::histogram::{BinEdges, ClassHistogram, Impurity, MomentHistogram};
use crate::metrics::{OpCounter, ShardCounters};
use crate::store::DatasetView;
use crate::util::rng::Rng;

/// A labeled dataset behind a [`DatasetView`]: the training-time facade
/// every Chapter 3 solver consumes. Borrow one from a dense
/// [`LabeledDataset`] with [`TrainSet::of`], or assemble one over a
/// [`crate::store::ColumnStore`] for the columnar / out-of-core path.
#[derive(Clone, Copy)]
pub struct TrainSet<'a> {
    pub x: &'a dyn DatasetView,
    /// Class index for classification; value for regression.
    pub y: &'a [f32],
    /// 0 for regression.
    pub n_classes: usize,
}

impl<'a> TrainSet<'a> {
    pub fn of(ds: &'a LabeledDataset) -> TrainSet<'a> {
        TrainSet { x: &ds.x, y: &ds.y, n_classes: ds.n_classes }
    }

    pub fn is_regression(&self) -> bool {
        self.n_classes == 0
    }

    pub fn n(&self) -> usize {
        self.x.n_rows()
    }
}

/// A chosen split.
#[derive(Clone, Debug)]
pub struct Split {
    pub feature: usize,
    /// Numeric threshold: go left if x[feature] < threshold.
    pub threshold: f32,
    /// Weighted child impurity μ_ft at the chosen split.
    pub child_impurity: f64,
}

impl Split {
    /// FNV-1a digest of the chosen split — feature, exact threshold
    /// bits, and exact impurity bits — the answer the perf-gate pins
    /// next to the insertion counts.
    pub fn digest(&self) -> u64 {
        crate::util::digest::fnv1a_u64s([
            self.feature as u64,
            self.threshold.to_bits() as u64,
            self.child_impurity.to_bits(),
        ])
    }
}

/// Node-splitting context shared by both solvers.
pub struct SplitContext<'a> {
    pub ds: TrainSet<'a>,
    /// Row indices belonging to this node.
    pub rows: &'a [usize],
    /// Candidate features at this node (already subsampled by the tree).
    pub features: &'a [usize],
    /// Per-candidate-feature bin edges.
    pub edges: Vec<BinEdges>,
    pub impurity: Impurity,
    /// Histogram-insertion counter (the paper's budget metric).
    pub counter: &'a OpCounter,
}

/// Fill one feature's classification histogram from a chunk-aligned
/// column sweep ([`DatasetView::for_each_col_block_quant`]): on a
/// [`crate::store::ColumnStore`] each chunk is decoded element-fused
/// into an arena run buffer (no full-chunk `Vec<f32>`), and insertions
/// are counted once per run — totals and bin state identical to the
/// per-element path. An integer-domain I8 store hands the raw codes
/// plus the run's header instead, and the histogram bins them through
/// a code→bin LUT ([`ClassHistogram::fill_i8`]) — same bins, same
/// digests, at most 256 decodes per run.
fn fill_class(
    h: &mut ClassHistogram,
    x: &dyn DatasetView,
    feature: usize,
    rows: &[usize],
    y: &[f32],
    counter: &OpCounter,
) {
    x.for_each_col_block_quant(feature, rows, &mut |start, block| match block {
        crate::store::ColBlock::F32(vals) => {
            let classes = rows[start..start + vals.len()].iter().map(|&r| y[r] as usize);
            h.fill(vals, classes, counter);
        }
        crate::store::ColBlock::I8 { header, codes } => {
            let classes = rows[start..start + codes.len()].iter().map(|&r| y[r] as usize);
            h.fill_i8(&header, codes, classes, counter);
        }
    });
}

/// Regression sibling of [`fill_class`].
fn fill_moment(
    h: &mut MomentHistogram,
    x: &dyn DatasetView,
    feature: usize,
    rows: &[usize],
    y: &[f32],
    counter: &OpCounter,
) {
    x.for_each_col_block_quant(feature, rows, &mut |start, block| match block {
        crate::store::ColBlock::F32(vals) => {
            let ys = rows[start..start + vals.len()].iter().map(|&r| y[r] as f64);
            h.fill(vals, ys, counter);
        }
        crate::store::ColBlock::I8 { header, codes } => {
            let ys = rows[start..start + codes.len()].iter().map(|&r| y[r] as f64);
            h.fill_i8(&header, codes, ys, counter);
        }
    });
}

/// Exact solver: fill every feature histogram with every node point, then
/// scan all thresholds. `n·m` insertions, one column scan per feature.
pub fn solve_exactly(ctx: &SplitContext) -> Option<Split> {
    solve_exact_cached(ctx).map(|(s, _)| s)
}

/// [`solve_exactly`], additionally returning the filled per-feature
/// histograms as a [`SplitCache`] for later warm-started
/// [`refresh_split`] calls.
pub fn solve_exact_cached(ctx: &SplitContext) -> Option<(Split, SplitCache)> {
    let regression = ctx.ds.is_regression();
    let mut cache = SplitCache {
        features: ctx.features.to_vec(),
        edges: ctx.edges.clone(),
        ranges: ctx.features.iter().map(|&f| ctx.ds.x.col_range(f)).collect(),
        impurity: ctx.impurity,
        n_classes: ctx.ds.n_classes,
        hists_c: Vec::new(),
        hists_r: Vec::new(),
        n_rows_seen: ctx.rows.len(),
    };
    for (fi, &f) in ctx.features.iter().enumerate() {
        if regression {
            let mut h = MomentHistogram::new(ctx.edges[fi].clone());
            fill_moment(&mut h, ctx.ds.x, f, ctx.rows, ctx.ds.y, ctx.counter);
            cache.hists_r.push(h);
        } else {
            let mut h = ClassHistogram::new(ctx.edges[fi].clone(), ctx.ds.n_classes);
            fill_class(&mut h, ctx.ds.x, f, ctx.rows, ctx.ds.y, ctx.counter);
            cache.hists_c.push(h);
        }
    }
    cache.best_split().map(|s| (s, cache))
}

/// A node's filled per-feature histograms, kept after an exact solve so
/// an append only pays for the **new** rows: [`refresh_split`] inserts
/// them on top and re-scans thresholds. For classification the histogram
/// counts are order-independent integers, so a refreshed split is
/// *identical* to a cold exact solve over the grown node; regression
/// moment sums agree up to f64 addition order.
///
/// The cache is only valid while the node's bin edges stay valid: if an
/// appended value falls outside a feature's cached edge span, that
/// feature's histogram must be rebuilt (cold) — [`refresh_split`] checks
/// via [`DatasetView::col_range`] (free on a
/// [`crate::store::ColumnStore`]) and rebuilds exactly the features that
/// need it. Random-edge (ExtraTrees) nodes are not cacheable: their
/// edges consume RNG state a refresh cannot replay.
pub struct SplitCache {
    pub features: Vec<usize>,
    pub edges: Vec<BinEdges>,
    /// Per-feature [`DatasetView::col_range`] at cache-build time: the
    /// drift check compares bit patterns against the current view, so a
    /// feature rebuilds exactly when a cold solve would see different
    /// ranges (and hence different equal-width edges).
    ranges: Vec<(f32, f32)>,
    pub impurity: Impurity,
    pub n_classes: usize,
    hists_c: Vec<ClassHistogram>,
    hists_r: Vec<MomentHistogram>,
    /// Rows inserted so far (diagnostics; refresh adds to it).
    pub n_rows_seen: usize,
}

impl SplitCache {
    fn is_regression(&self) -> bool {
        self.n_classes == 0
    }

    /// Best split over the cached histograms (the shared scan of the
    /// exact solver and the refresh path).
    fn best_split(&self) -> Option<Split> {
        let mut best: Option<(f64, usize, usize)> = None; // (mu, fi, t)
        for fi in 0..self.features.len() {
            let scans: Vec<(f64, f64)> = if self.is_regression() {
                self.hists_r[fi].scan_thresholds()
            } else {
                self.hists_c[fi].scan_thresholds(self.impurity)
            };
            for (t, &(mu, _)) in scans.iter().enumerate() {
                if best.map_or(true, |(bm, _, _)| mu < bm) {
                    best = Some((mu, fi, t));
                }
            }
        }
        best.map(|(mu, fi, t)| Split {
            feature: self.features[fi],
            threshold: self.edges[fi].edges[t + 1],
            child_impurity: mu,
        })
    }
}

/// Warm-started node re-split after an append: insert only `new_rows`
/// into the cached histograms (rebuilding just the features whose cached
/// edge span no longer covers the data), then re-scan every threshold.
/// `all_rows` is the node's full row set including the appended rows —
/// used only when a rebuild is needed. Cost: `|new_rows| · m` insertions
/// (+ full refills for out-of-range features), against the cold solve's
/// `|all_rows| · m`.
pub fn refresh_split(
    cache: &mut SplitCache,
    ds: &TrainSet,
    all_rows: &[usize],
    new_rows: &[usize],
    counter: &OpCounter,
) -> Option<Split> {
    let regression = cache.is_regression();
    debug_assert_eq!(regression, ds.is_regression());
    for fi in 0..cache.features.len() {
        let f = cache.features[fi];
        let (span_lo, span_hi) = cache.ranges[fi];
        let (lo, hi) = ds.x.col_range(f);
        if lo.to_bits() != span_lo.to_bits() || hi.to_bits() != span_hi.to_bits() {
            // Range drift: this feature's bins no longer match what a
            // cold solve would use — rebuild it cold over the full node
            // with fresh equal-width edges over the current range.
            let t = cache.edges[fi].n_bins();
            cache.edges[fi] = BinEdges::equal_width(lo, hi, t);
            cache.ranges[fi] = (lo, hi);
            if regression {
                let mut h = MomentHistogram::new(cache.edges[fi].clone());
                fill_moment(&mut h, ds.x, f, all_rows, ds.y, counter);
                cache.hists_r[fi] = h;
            } else {
                let mut h = ClassHistogram::new(cache.edges[fi].clone(), cache.n_classes);
                fill_class(&mut h, ds.x, f, all_rows, ds.y, counter);
                cache.hists_c[fi] = h;
            }
            continue;
        }
        if regression {
            fill_moment(&mut cache.hists_r[fi], ds.x, f, new_rows, ds.y, counter);
        } else {
            fill_class(&mut cache.hists_c[fi], ds.x, f, new_rows, ds.y, counter);
        }
    }
    cache.n_rows_seen += new_rows.len();
    cache.best_split()
}

/// MABSplit (Algorithm 3): batched successive elimination over (f, t)
/// arms. Uses permutation sampling (§3.3.2: without replacement is what
/// the implementation ships), so when the budget reaches n the histograms
/// hold the entire node and the estimates are exact — the algorithm
/// degrades to a batched version of the exact solver, never worse.
pub fn solve_mab(ctx: &SplitContext, batch_size: usize, delta: f64, seed: u64) -> Option<Split> {
    solve_mab_threaded(ctx, batch_size, delta, seed, 1)
}

/// [`solve_mab`] with shard-parallel batch observation: the surviving
/// arms' *features* are sharded onto the shared worker pool (each feature
/// histogram stays on one shard and fills from its own column scan), with
/// per-shard insertion counters merged into `ctx.counter` at batch end.
/// For a fixed seed the chosen split and the insertion totals are
/// bit-identical for every `threads` value (see
/// [`BanditConfig::threads`]).
pub fn solve_mab_threaded(
    ctx: &SplitContext,
    batch_size: usize,
    delta: f64,
    seed: u64,
    threads: usize,
) -> Option<Split> {
    let n = ctx.rows.len();
    let m = ctx.features.len();
    if n == 0 || m == 0 {
        return None;
    }
    // Thresholds per feature (T−1 internal edges each).
    let t_per: Vec<usize> = ctx.edges.iter().map(|e| e.n_bins().saturating_sub(1)).collect();
    let arm_offsets: Vec<usize> = {
        let mut off = vec![0usize];
        for &t in &t_per {
            off.push(off.last().unwrap() + t);
        }
        off
    };
    let n_arms = *arm_offsets.last().unwrap();
    if n_arms == 0 {
        return None;
    }

    let mut arms = MabSplitArms {
        ctx,
        arm_offsets: &arm_offsets,
        hists_c: Vec::new(),
        hists_r: Vec::new(),
        mu: vec![f64::INFINITY; n_arms],
        se: vec![f64::INFINITY; n_arms],
        n_inserted: 0,
        full: vec![false; m],
    };
    // Lazily created histograms per candidate feature.
    if ctx.ds.is_regression() {
        arms.hists_r = ctx.edges.iter().map(|e| MomentHistogram::new(e.clone())).collect();
    } else {
        arms.hists_c = ctx
            .edges
            .iter()
            .map(|e| ClassHistogram::new(e.clone(), ctx.ds.n_classes))
            .collect();
    }

    let bcfg = BanditConfig {
        delta: delta / n_arms as f64,
        batch_size,
        sampling: Sampling::Permutation,
        keep: 1,
        seed,
        threads,
    };
    let r = {
        let _span = crate::obs::span("solver.mabsplit");
        successive_elimination(&mut arms, &bcfg)
    };
    let best = r.best[0];
    let fi = arm_offsets.partition_point(|&o| o <= best) - 1;
    let t = best - arm_offsets[fi];
    let mu = arms.mu[best];
    if !mu.is_finite() {
        return None;
    }
    Some(Split {
        feature: ctx.features[fi],
        threshold: ctx.edges[fi].edges[t + 1],
        child_impurity: mu,
    })
}

/// Arms for MABSplit: arm id = arm_offsets[fi] + t.
struct MabSplitArms<'a, 'b> {
    ctx: &'b SplitContext<'a>,
    arm_offsets: &'b [usize],
    hists_c: Vec<ClassHistogram>,
    hists_r: Vec<MomentHistogram>,
    /// Cached per-arm estimates, refreshed after every observed batch.
    mu: Vec<f64>,
    se: Vec<f64>,
    n_inserted: usize,
    /// Features whose histogram already holds the full node (exact).
    full: Vec<bool>,
}

impl<'a, 'b> MabSplitArms<'a, 'b> {
    /// Sorted distinct feature indices among the surviving arms.
    fn features_of(&self, arms: &[usize]) -> Vec<usize> {
        let mut fis: Vec<usize> = arms
            .iter()
            .map(|&a| self.arm_offsets.partition_point(|&o| o <= a) - 1)
            .collect();
        fis.sort_unstable();
        fis.dedup();
        fis
    }

    fn refresh_feature(&mut self, fi: usize) {
        let scans = if self.ctx.ds.is_regression() {
            self.hists_r[fi].scan_thresholds()
        } else {
            self.hists_c[fi].scan_thresholds(self.ctx.impurity)
        };
        let off = self.arm_offsets[fi];
        // Duplicate-threshold collapse: consecutive thresholds separated
        // only by (so-far) empty bins have *identical* split behaviour —
        // e.g. a binary one-hot feature yields T-1 copies of one split.
        // Keeping every copy alive stalls elimination forever (tied arms
        // are never separable), so all but the first representative are
        // parked at +inf. The kept arm's estimate is updated identically,
        // so no split quality is lost on the evidence seen so far.
        let mut prev = f64::NAN;
        for (t, (mu, se)) in scans.into_iter().enumerate() {
            if t > 0 && mu == prev {
                self.mu[off + t] = f64::INFINITY;
                self.se[off + t] = f64::INFINITY;
            } else {
                self.mu[off + t] = mu;
                self.se[off + t] = se;
            }
            prev = mu;
        }
    }
}

impl<'a, 'b> AdaptiveArms for MabSplitArms<'a, 'b> {
    fn n_arms(&self) -> usize {
        *self.arm_offsets.last().unwrap()
    }

    fn ref_len(&self) -> usize {
        self.ctx.rows.len()
    }

    fn observe_shard(&mut self, arms: &[usize], batch: &[usize]) {
        let fis = self.features_of(arms);
        // Resolve the batch to dataset rows once (arena scratch); every
        // feature's chunk-aligned column sweep reuses it.
        let mut rows = crate::kernels::scratch::idx_buf(batch.len());
        for (slot, &bi) in rows.iter_mut().zip(batch) {
            *slot = self.ctx.rows[bi];
        }
        for &fi in &fis {
            let f = self.ctx.features[fi];
            if self.ctx.ds.is_regression() {
                fill_moment(
                    &mut self.hists_r[fi],
                    self.ctx.ds.x,
                    f,
                    &rows,
                    self.ctx.ds.y,
                    self.ctx.counter,
                );
            } else {
                fill_class(
                    &mut self.hists_c[fi],
                    self.ctx.ds.x,
                    f,
                    &rows,
                    self.ctx.ds.y,
                    self.ctx.counter,
                );
            }
            self.refresh_feature(fi);
        }
        self.n_inserted += batch.len();
    }

    fn observe_batch(&mut self, arms: &[usize], batch: &[usize], par: Option<ParCtx>) {
        let Some(p) = par else {
            self.observe_shard(arms, batch);
            return;
        };
        let fis = self.features_of(arms);
        if fis.len() < 2 {
            self.observe_shard(arms, batch);
            return;
        }
        // One task per surviving feature: a histogram is only ever touched
        // by its own shard, each shard fills from its own chunk-aligned
        // column sweep (fused-decoded, arena scratch on the worker), and
        // inserts happen in batch order within it, so the bins match the
        // sequential path bit-for-bit. Insertions are counted on per-shard
        // counters and merged once at batch end.
        let ctx = self.ctx;
        let counters = ShardCounters::new(fis.len());
        let mut rows = crate::kernels::scratch::idx_buf(batch.len());
        for (slot, &bi) in rows.iter_mut().zip(batch) {
            *slot = ctx.rows[bi];
        }
        let rows_ref: &[usize] = &rows;
        let regression = ctx.ds.is_regression();
        if regression {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(fis.len());
            let mut si = 0usize;
            for (fi, hist) in self.hists_r.iter_mut().enumerate() {
                if fis.binary_search(&fi).is_err() {
                    continue;
                }
                let ctr = counters.shard(si);
                si += 1;
                let f = ctx.features[fi];
                tasks.push(Box::new(move || {
                    fill_moment(hist, ctx.ds.x, f, rows_ref, ctx.ds.y, ctr);
                }));
            }
            p.pool.run(tasks);
        } else {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(fis.len());
            let mut si = 0usize;
            for (fi, hist) in self.hists_c.iter_mut().enumerate() {
                if fis.binary_search(&fi).is_err() {
                    continue;
                }
                let ctr = counters.shard(si);
                si += 1;
                let f = ctx.features[fi];
                tasks.push(Box::new(move || {
                    fill_class(hist, ctx.ds.x, f, rows_ref, ctx.ds.y, ctr);
                }));
            }
            p.pool.run(tasks);
        }
        counters.merge_into(ctx.counter);
        for &fi in &fis {
            self.refresh_feature(fi);
        }
        self.n_inserted += batch.len();
    }

    fn estimate(&self, arm: usize) -> f64 {
        self.mu[arm]
    }

    fn ci(&self, arm: usize, _n_used: usize, delta: f64) -> f64 {
        // Delta-method SE scaled by the z-quantile implied by δ':
        // C = se · sqrt(2 ln(1/δ)).
        self.se[arm] * (2.0 * (1.0 / delta).ln()).sqrt()
    }

    fn exact(&mut self, arm: usize) -> f64 {
        // Permutation sampling means a full-budget run has already seen all
        // points exactly once; recompute from a fresh full histogram only
        // if coverage is partial, and only once per feature.
        let fi = self.arm_offsets.partition_point(|&o| o <= arm) - 1;
        if self.n_inserted < self.ctx.rows.len() && !self.full[fi] {
            let f = self.ctx.features[fi];
            if self.ctx.ds.is_regression() {
                let mut h = MomentHistogram::new(self.ctx.edges[fi].clone());
                fill_moment(
                    &mut h,
                    self.ctx.ds.x,
                    f,
                    self.ctx.rows,
                    self.ctx.ds.y,
                    self.ctx.counter,
                );
                self.hists_r[fi] = h;
            } else {
                let mut h = ClassHistogram::new(self.ctx.edges[fi].clone(), self.ctx.ds.n_classes);
                fill_class(
                    &mut h,
                    self.ctx.ds.x,
                    f,
                    self.ctx.rows,
                    self.ctx.ds.y,
                    self.ctx.counter,
                );
                self.hists_c[fi] = h;
            }
            self.refresh_feature(fi);
            self.full[fi] = true;
        }
        self.mu[arm]
    }
}

/// Per-feature (min, max) ranges over any [`DatasetView`] — done once per
/// forest, outside the insertion budget (it is not a histogram
/// insertion). On a [`crate::store::ColumnStore`] this folds the
/// per-chunk stats: no decode, no disk.
pub fn feature_ranges_view(x: &dyn DatasetView) -> Vec<(f32, f32)> {
    (0..x.n_cols()).map(|c| x.col_range(c)).collect()
}

/// [`feature_ranges_view`] over a dense labeled dataset.
pub fn feature_ranges(ds: &LabeledDataset) -> Vec<(f32, f32)> {
    feature_ranges_view(&ds.x)
}

/// Build bin edges for a node's candidate features.
pub fn make_edges(
    features: &[usize],
    ranges: &[(f32, f32)],
    t_bins: usize,
    random_edges: bool,
    rng: &mut Rng,
) -> Vec<BinEdges> {
    features
        .iter()
        .map(|&f| {
            let (lo, hi) = ranges[f];
            if random_edges {
                BinEdges::random(lo, hi, t_bins, rng)
            } else {
                BinEdges::equal_width(lo, hi, t_bins)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tabular::{make_classification, make_regression};
    use crate::store::{ColumnStore, StoreOptions};

    fn ctx_for<'a>(
        ds: &'a LabeledDataset,
        rows: &'a [usize],
        features: &'a [usize],
        counter: &'a OpCounter,
        t_bins: usize,
    ) -> SplitContext<'a> {
        let ranges = feature_ranges(ds);
        let mut rng = Rng::new(1);
        let edges = make_edges(features, &ranges, t_bins, false, &mut rng);
        SplitContext {
            ds: TrainSet::of(ds),
            rows,
            features,
            edges,
            impurity: Impurity::Gini,
            counter,
        }
    }

    #[test]
    fn exact_finds_informative_feature() {
        let ds = make_classification(500, 8, 2, 2, 3.0, 7);
        let rows: Vec<usize> = (0..ds.x.n).collect();
        let features: Vec<usize> = (0..8).collect();
        let c = OpCounter::new();
        let split = solve_exactly(&ctx_for(&ds, &rows, &features, &c, 10)).unwrap();
        // The chosen feature must actually separate classes better than a
        // random one: its impurity should be clearly below the parent's.
        assert!(split.child_impurity < 0.45, "impurity {}", split.child_impurity);
        assert_eq!(c.get(), 500 * 8);
    }

    #[test]
    fn mabsplit_agrees_with_exact_and_saves_insertions() {
        let mut agree = 0;
        for seed in 0..5 {
            let ds = make_classification(4000, 10, 3, 2, 2.5, seed);
            let rows: Vec<usize> = (0..ds.x.n).collect();
            let features: Vec<usize> = (0..10).collect();
            let c_exact = OpCounter::new();
            let exact = solve_exactly(&ctx_for(&ds, &rows, &features, &c_exact, 10)).unwrap();
            let c_mab = OpCounter::new();
            let mab = solve_mab(&ctx_for(&ds, &rows, &features, &c_mab, 10), 100, 0.01, seed)
                .unwrap();
            if exact.feature == mab.feature && (exact.threshold - mab.threshold).abs() < 1e-6 {
                agree += 1;
            } else {
                // must still be a near-optimal split
                assert!(
                    mab.child_impurity <= exact.child_impurity + 0.02,
                    "seed {seed}: mab {} vs exact {}",
                    mab.child_impurity,
                    exact.child_impurity
                );
            }
            assert!(
                c_mab.get() < c_exact.get(),
                "seed {seed}: MABSplit used {} ≥ exact {}",
                c_mab.get(),
                c_exact.get()
            );
        }
        assert!(agree >= 3, "only {agree}/5 exact split agreements");
    }

    #[test]
    fn mabsplit_on_regression() {
        let ds = make_regression(3000, 8, 2, 0.3, 3);
        let rows: Vec<usize> = (0..ds.x.n).collect();
        let features: Vec<usize> = (0..8).collect();
        let c = OpCounter::new();
        let ranges = feature_ranges(&ds);
        let mut rng = Rng::new(1);
        let edges = make_edges(&features, &ranges, 10, false, &mut rng);
        let ctx = SplitContext {
            ds: TrainSet::of(&ds),
            rows: &rows,
            features: &features,
            edges,
            impurity: Impurity::Mse,
            counter: &c,
        };
        let mab = solve_mab(&ctx, 100, 0.01, 9).unwrap();
        // exact for comparison
        let c2 = OpCounter::new();
        let ranges2 = feature_ranges(&ds);
        let mut rng2 = Rng::new(1);
        let ctx2 = SplitContext {
            ds: TrainSet::of(&ds),
            rows: &rows,
            features: &features,
            edges: make_edges(&features, &ranges2, 10, false, &mut rng2),
            impurity: Impurity::Mse,
            counter: &c2,
        };
        let exact = solve_exactly(&ctx2).unwrap();
        assert!(mab.child_impurity <= exact.child_impurity * 1.25 + 1e-9);
    }

    #[test]
    fn mabsplit_complexity_flat_in_n() {
        // Appendix B.2: the per-split sample complexity should not grow
        // with dataset size when the gaps are n-independent.
        let insertions = |n: usize| {
            let ds = make_classification(n, 10, 3, 2, 2.5, 11);
            let rows: Vec<usize> = (0..ds.x.n).collect();
            let features: Vec<usize> = (0..10).collect();
            let c = OpCounter::new();
            let _ = solve_mab(&ctx_for(&ds, &rows, &features, &c, 10), 100, 0.01, 1).unwrap();
            c.get()
        };
        let small = insertions(2_000);
        let large = insertions(20_000);
        assert!(
            (large as f64) < (small as f64) * 3.0,
            "insertions should be ~flat in n: {small} -> {large}"
        );
    }

    #[test]
    fn parallel_mabsplit_bit_identical_and_same_insertions() {
        // Tentpole acceptance: same split (feature, threshold bits,
        // impurity bits) AND same histogram-insertion totals for every
        // thread count, classification and regression alike.
        for regression in [false, true] {
            let ds = if regression {
                make_regression(3_000, 8, 2, 0.3, 21)
            } else {
                make_classification(3_000, 10, 3, 2, 2.5, 21)
            };
            let m = ds.x.d;
            let rows: Vec<usize> = (0..ds.x.n).collect();
            let features: Vec<usize> = (0..m).collect();
            let run = |threads: usize| {
                let c = OpCounter::new();
                let ranges = feature_ranges(&ds);
                let mut rng = Rng::new(1);
                let ctx = SplitContext {
                    ds: TrainSet::of(&ds),
                    rows: &rows,
                    features: &features,
                    edges: make_edges(&features, &ranges, 10, false, &mut rng),
                    impurity: if regression { Impurity::Mse } else { Impurity::Gini },
                    counter: &c,
                };
                let s = solve_mab_threaded(&ctx, 100, 0.01, 77, threads).unwrap();
                (s.feature, s.threshold.to_bits(), s.child_impurity.to_bits(), c.get())
            };
            let seq = run(1);
            for threads in [2usize, 4] {
                assert_eq!(
                    run(threads),
                    seq,
                    "regression={regression} threads={threads} diverged"
                );
            }
        }
    }

    #[test]
    fn column_store_split_bit_identical_to_matrix() {
        // The storage leg of the determinism contract, at the solver
        // boundary: a ColumnStore(F32)-backed TrainSet yields the same
        // split, bit for bit, with the same insertion totals.
        let ds = make_classification(3_000, 10, 3, 2, 2.5, 31);
        let rows: Vec<usize> = (0..ds.x.n).collect();
        let features: Vec<usize> = (0..ds.x.d).collect();
        let cs = ColumnStore::from_matrix(
            &ds.x,
            &StoreOptions { rows_per_chunk: 256, ..Default::default() },
        )
        .unwrap();
        let run = |ts: TrainSet, threads: usize| {
            let c = OpCounter::new();
            let ranges = feature_ranges_view(ts.x);
            let mut rng = Rng::new(1);
            let ctx = SplitContext {
                ds: ts,
                rows: &rows,
                features: &features,
                edges: make_edges(&features, &ranges, 10, false, &mut rng),
                impurity: Impurity::Gini,
                counter: &c,
            };
            let s = solve_mab_threaded(&ctx, 100, 0.01, 77, threads).unwrap();
            (s.feature, s.threshold.to_bits(), s.child_impurity.to_bits(), c.get())
        };
        let dense = run(TrainSet::of(&ds), 1);
        for threads in [1usize, 2, 4, 8] {
            let columnar = run(
                TrainSet { x: &cs, y: &ds.y, n_classes: ds.n_classes },
                threads,
            );
            assert_eq!(columnar, dense, "threads={threads}");
        }
    }

    #[test]
    fn refresh_split_identical_to_cold_exact_after_append() {
        use crate::util::testkit;
        let base = testkit::clusterable(3_000, 10, 3, 6.0, 61);
        let (ax, ay) = testkit::append_within(&base.x, Some(&base.y), 120, 61);
        let mut full_rows: Vec<Vec<f32>> = (0..base.x.n).map(|i| base.x.row(i).to_vec()).collect();
        full_rows.extend((0..ax.n).map(|i| ax.row(i).to_vec()));
        let full = LabeledDataset {
            x: crate::data::Matrix::from_rows(full_rows).unwrap(),
            y: base.y.iter().chain(&ay).copied().collect(),
            n_classes: 3,
        };
        let features: Vec<usize> = (0..10).collect();
        let base_rows: Vec<usize> = (0..base.x.n).collect();
        let all_rows: Vec<usize> = (0..full.x.n).collect();
        let new_rows: Vec<usize> = (base.x.n..full.x.n).collect();

        // Previous solve on the base node, cache kept.
        let c_prev = OpCounter::new();
        let (_, mut cache) =
            solve_exact_cached(&ctx_for(&base, &base_rows, &features, &c_prev, 10)).unwrap();

        // Cold exact on the grown node (appends stay inside the column
        // ranges by construction, so cold edges == cached edges).
        let c_cold = OpCounter::new();
        let cold = solve_exactly(&ctx_for(&full, &all_rows, &features, &c_cold, 10)).unwrap();

        let c_warm = OpCounter::new();
        let warm =
            refresh_split(&mut cache, &TrainSet::of(&full), &all_rows, &new_rows, &c_warm)
                .unwrap();
        assert_eq!(
            (warm.feature, warm.threshold.to_bits(), warm.child_impurity.to_bits()),
            (cold.feature, cold.threshold.to_bits(), cold.child_impurity.to_bits()),
            "warm refresh must reproduce the cold exact split bit-for-bit"
        );
        assert!(
            c_warm.get() * 2 < c_cold.get(),
            "warm {} vs cold {}",
            c_warm.get(),
            c_cold.get()
        );
        assert_eq!(cache.n_rows_seen, full.x.n);
    }

    #[test]
    fn refresh_split_rebuilds_features_whose_range_drifted() {
        use crate::util::testkit;
        let base = testkit::clusterable(2_000, 6, 2, 6.0, 67);
        // One appended row escapes feature 2's range; the rest stay in.
        let (mut ax, ay) = testkit::append_within(&base.x, Some(&base.y), 40, 67);
        let (_, hi) = crate::store::DatasetView::col_range(&base.x, 2);
        ax.row_mut(0)[2] = hi + 25.0;
        let mut full_rows: Vec<Vec<f32>> = (0..base.x.n).map(|i| base.x.row(i).to_vec()).collect();
        full_rows.extend((0..ax.n).map(|i| ax.row(i).to_vec()));
        let full = LabeledDataset {
            x: crate::data::Matrix::from_rows(full_rows).unwrap(),
            y: base.y.iter().chain(&ay).copied().collect(),
            n_classes: 2,
        };
        let features: Vec<usize> = (0..6).collect();
        let base_rows: Vec<usize> = (0..base.x.n).collect();
        let all_rows: Vec<usize> = (0..full.x.n).collect();
        let new_rows: Vec<usize> = (base.x.n..full.x.n).collect();

        let c_prev = OpCounter::new();
        let (_, mut cache) =
            solve_exact_cached(&ctx_for(&base, &base_rows, &features, &c_prev, 8)).unwrap();
        let c_cold = OpCounter::new();
        let cold = solve_exactly(&ctx_for(&full, &all_rows, &features, &c_cold, 8)).unwrap();
        let c_warm = OpCounter::new();
        let warm =
            refresh_split(&mut cache, &TrainSet::of(&full), &all_rows, &new_rows, &c_warm)
                .unwrap();
        assert_eq!(
            (warm.feature, warm.threshold.to_bits(), warm.child_impurity.to_bits()),
            (cold.feature, cold.threshold.to_bits(), cold.child_impurity.to_bits()),
            "rebuilt feature must match the cold edges exactly"
        );
        // One feature refilled in full (n_all), five incremental (n_new).
        assert_eq!(c_warm.get(), full.x.n as u64 + 5 * new_rows.len() as u64);
        assert!(c_warm.get() * 2 < c_cold.get());
    }

    #[test]
    fn tiny_nodes_fall_back_gracefully() {
        let ds = make_classification(30, 5, 2, 2, 2.0, 13);
        let rows: Vec<usize> = (0..ds.x.n).collect();
        let features: Vec<usize> = (0..5).collect();
        let c = OpCounter::new();
        let mab = solve_mab(&ctx_for(&ds, &rows, &features, &c, 6), 100, 0.01, 1);
        assert!(mab.is_some());
        // With n < batch the solver inserts everything once: ≤ 2×n·m.
        assert!(c.get() <= 2 * 30 * 5);
    }
}
