//! Batched compute microkernels — the zero-dependency layer every hot
//! loop in the crate bottoms out in.
//!
//! The thesis shrinks *how many* samples each subroutine needs; this
//! module shrinks *what each sample costs*. Before it existed, every
//! bandit pull was a scalar `get`/`read_row_at`/`dot` call (one chunk-map
//! lookup — and, on lossy stores, one LRU probe — per element), and every
//! I8/F16 chunk was decoded to a fresh `Vec<f32>` before a single
//! multiply happened. The kernels here operate on a row-block ×
//! coordinate-block at a time, so each chunk is touched once per batch,
//! and the quantized codecs are decoded element-fused inside the
//! reduction loop — no intermediate buffer, no cache traffic.
//!
//! | module | role |
//! |---|---|
//! | [`reduce`]  | the one fixed-lane (8-wide, autovectorizable) reduction family: `dot_f32`, `l1`, `l2`, `l2_sq`, `cosine`. Every copy that used to live in `data/distance.rs`, `util/linalg.rs`, and the MABSplit column scan now delegates here. |
//! | [`quant`]   | fused quantized-domain element kernels: IEEE binary16 conversion and the per-chunk affine I8 header algebra, applied once per chunk run instead of once per element. Bit-for-bit identical to `store/codec.rs`'s decode (codec delegates to these). |
//! | [`scratch`] | per-worker reusable scratch arenas: thread-local buffer pools with grow-event instrumentation, so batched kernels perform zero heap allocations in steady state. |
//!
//! # Kernel contract
//!
//! Every kernel in this module — and every batched
//! [`crate::store::DatasetView`] hook built on it — obeys three rules:
//!
//! 1. **Accumulation order is pinned.** An 8-lane reduction accumulates
//!    element `c` into lane `c % 8` (f32 lanes), folds the lanes in lane
//!    order, then adds the `n % 8` tail elements serially — exactly the
//!    shape the seed's hand-rolled loops used, so F32 results are
//!    bit-identical to the scalar path no matter how the surrounding
//!    call is batched, tiled, or sharded. Batching may reorder *which
//!    row is reduced when*, never the order *within* a reduction.
//! 2. **Scratch is borrowed, never owned.** Kernels take output slices
//!    from the caller or draw reusable buffers from [`scratch`]; they do
//!    not allocate on the hot path. [`scratch::grow_events`] counts the
//!    (thread-local) arena growths so tests can assert steady-state
//!    zero-allocation behavior.
//! 3. **Determinism survives threading.** Kernels are pure functions of
//!    their inputs; per-worker arenas are thread-local; nothing reads
//!    thread identity. A shard boundary or tile size change never
//!    reaches the arithmetic.
//!
//! Lossy codecs keep their published semantics: the fused I8/F16 element
//! kernels compute the *same expression* as a full-chunk decode
//! (`(min + scale·u)` in f64, cast to f32; binary16 via
//! [`quant::f16_to_f32`]), so a fused read is bit-identical to
//! decode-then-read — the codec `error_bound` contract is inherited, not
//! re-derived.
//!
//! # The documented I8 exception: integer-domain reductions
//!
//! The one *deliberate* departure from rule 1 is the integer-domain I8
//! path ([`reduce::dot_u8_i8`] + [`quant::quantize_weights`], enabled by
//! `StoreOptions::int_domain` on in-RAM encoded I8 stores). Instead of
//! decoding each element to f32 and reducing in float, it applies the
//! affine header algebra once per chunk run — `⟨row, q⟩ over a chunk =
//! base + W·Σ u_c·w8_c`, with the per-column weights `q_c·scale_c`
//! snapped onto an i8 grid of step `W` — and accumulates the u8×i8
//! products exactly in i32. The result is *not* bit-identical to the
//! decode-to-f32 chain: it is a codec-level semantics change, bounded by
//! the documented envelope `(W/2)·Σ u_c` per chunk run, with its own
//! perf-gate digest baselines. F32 and F16 paths are untouched and stay
//! bit-identical; within the I8 integer path, determinism still holds —
//! identical answers for a fixed seed at any thread count, because i32
//! accumulation is exact and the per-run quantization depends only on
//! the chunk headers and the query.

pub mod quant;
pub mod reduce;
pub mod scratch;

pub use reduce::{cosine, dot_f32, dot_u8_i8, l1, l2, l2_sq, LANES};
