//! The crate's one fixed-lane reduction family.
//!
//! Before `kernels/` existed, three copies of the same 8-lane loop lived
//! in `data/distance.rs` (`lane_reduce!`), `util/linalg.rs` (`dot_f32`),
//! and the MABSplit column scan. They are deduplicated here; the old
//! homes re-export these implementations, so callers (and results) are
//! unchanged.
//!
//! Shape (see the module-level kernel contract): `LANES` f32
//! accumulators, element `c` folded into lane `c % LANES` in ascending
//! `c`, lanes summed in lane order, tail added serially after. LLVM
//! reliably autovectorizes this form.

/// Lane width of every fixed-lane kernel (8 × f32 = one 256-bit vector).
pub const LANES: usize = 8;

/// Dot product over f32 slices with f32 lane accumulation — the MIPS hot
/// path's reduction (result cast to f64 by callers that need it).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let mut acc = [0f32; LANES];
    for c in 0..chunks {
        let i = c * LANES;
        for l in 0..LANES {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * LANES..n {
        s += a[i] * b[i];
    }
    s
}

/// Integer-domain dot product: u8 codes × i8 weights accumulated in
/// i32 — the quantized hot path's reduction. Same fixed-lane shape as
/// [`dot_f32`] (element `c` into lane `c % LANES`, lanes folded in lane
/// order, serial tail); integer addition is exact, so the lane shape
/// here is purely for autovectorization, not for determinism.
///
/// Overflow headroom: `|u·w| ≤ 255·127 = 32 385`, so one i32 lane holds
/// over 66 000 products before it can wrap; with 8 lanes the reduction
/// is exact for any slice up to ≈ 530 000 elements — far beyond a chunk
/// run (`rows_per_chunk ≤ 16·1024` after normalization).
#[inline]
pub fn dot_u8_i8(u: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(u.len(), w.len());
    debug_assert!(u.len() < 530_000, "dot_u8_i8: i32 lanes could overflow");
    let n = u.len();
    let chunks = n / LANES;
    let mut acc = [0i32; LANES];
    for c in 0..chunks {
        let i = c * LANES;
        for l in 0..LANES {
            acc[l] += u[i + l] as i32 * w[i + l] as i32;
        }
    }
    let mut s: i32 = acc.iter().sum();
    for i in chunks * LANES..n {
        s += u[i] as i32 * w[i] as i32;
    }
    s
}

/// The shared pairwise lane reduction: f32 lanes over the full chunks,
/// lane totals widened to f64 and summed in lane order, f64 tail.
macro_rules! lane_reduce {
    ($a:expr, $b:expr, $op:expr) => {{
        let a = $a;
        let b = $b;
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let mut acc = [0f32; LANES];
        for c in 0..chunks {
            let i = c * LANES;
            for l in 0..LANES {
                acc[l] += $op(a[i + l], b[i + l]);
            }
        }
        let mut s = 0f64;
        for l in 0..LANES {
            s += acc[l] as f64;
        }
        for i in chunks * LANES..n {
            s += $op(a[i], b[i]) as f64;
        }
        s
    }};
}

/// Manhattan distance.
#[inline]
pub fn l1(a: &[f32], b: &[f32]) -> f64 {
    lane_reduce!(a, b, |x: f32, y: f32| (x - y).abs())
}

/// Euclidean distance.
#[inline]
pub fn l2(a: &[f32], b: &[f32]) -> f64 {
    l2_sq(a, b).sqrt()
}

/// Squared Euclidean distance (no sqrt), for callers that only compare.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f64 {
    lane_reduce!(a, b, |x: f32, y: f32| {
        let d = x - y;
        d * d
    })
}

/// Cosine distance: 1 − cos(a, b). Zero vectors get distance 1. Three
/// lane accumulators advance in lockstep so the pass stays single.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let mut dacc = [0f32; LANES];
    let mut aacc = [0f32; LANES];
    let mut bacc = [0f32; LANES];
    for c in 0..chunks {
        let i = c * LANES;
        for l in 0..LANES {
            dacc[l] += a[i + l] * b[i + l];
            aacc[l] += a[i + l] * a[i + l];
            bacc[l] += b[i + l] * b[i + l];
        }
    }
    let (mut d, mut na, mut nb) = (0f64, 0f64, 0f64);
    for l in 0..LANES {
        d += dacc[l] as f64;
        na += aacc[l] as f64;
        nb += bacc[l] as f64;
    }
    for i in chunks * LANES..n {
        d += (a[i] * b[i]) as f64;
        na += (a[i] * a[i]) as f64;
        nb += (b[i] * b[i]) as f64;
    }
    let denom = (na.sqrt() * nb.sqrt()).max(1e-20);
    // Clamp away float rounding: cos similarity lives in [-1, 1].
    (1.0 - d / denom).clamp(0.0, 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn reductions_match_naive_across_tail_lengths() {
        let mut r = Rng::new(77);
        for len in [0usize, 1, 7, 8, 9, 15, 16, 63, 64, 65, 1000] {
            let a: Vec<f32> = (0..len).map(|_| r.f32() * 2.0 - 1.0).collect();
            let b: Vec<f32> = (0..len).map(|_| r.f32() * 2.0 - 1.0).collect();
            let dot_naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot_f32(&a, &b) - dot_naive).abs() < 1e-3, "dot len {len}");
            let l1_naive: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs() as f64).sum();
            assert!((l1(&a, &b) - l1_naive).abs() < 1e-4, "l1 len {len}");
            let l2_naive: f64 =
                a.iter().zip(&b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
            assert!((l2_sq(&a, &b) - l2_naive).abs() < 1e-3, "l2_sq len {len}");
            assert!((l2(&a, &b) - l2_naive.sqrt()).abs() < 1e-4, "l2 len {len}");
        }
    }

    #[test]
    fn integer_dot_matches_naive_across_tail_lengths() {
        let mut r = Rng::new(91);
        for len in [0usize, 1, 7, 8, 9, 15, 16, 63, 64, 65, 1000] {
            let u: Vec<u8> = (0..len).map(|_| r.below(256) as u8).collect();
            let w: Vec<i8> = (0..len).map(|_| r.below(255) as i32 - 127).map(|v| v as i8).collect();
            let naive: i32 = u.iter().zip(&w).map(|(&x, &y)| x as i32 * y as i32).sum();
            assert_eq!(dot_u8_i8(&u, &w), naive, "len {len}");
        }
        // Extremes: every product at its magnitude ceiling, both signs.
        let u = vec![255u8; 1024];
        assert_eq!(dot_u8_i8(&u, &vec![127i8; 1024]), 1024 * 255 * 127);
        assert_eq!(dot_u8_i8(&u, &vec![-127i8; 1024]), -1024 * 255 * 127);
    }

    #[test]
    fn cosine_lane_form_matches_extremes() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-9);
        assert!(cosine(&[1.0, 0.0], &[2.0, 0.0]).abs() < 1e-9);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) - 2.0).abs() < 1e-9);
    }
}
