//! Per-worker reusable scratch arenas.
//!
//! Batched kernels need transient buffers (a gathered tile, a distance
//! vector, a translated index list). Allocating them per call would put
//! `malloc` back on the hot path the kernels exist to clear, so each
//! thread keeps small pools of `Vec`s that are borrowed RAII-style and
//! returned (capacity intact) on drop. After a warm-up call with the
//! steady-state shapes, no kernel call allocates.
//!
//! Instrumentation: every time a borrow has to *grow* a buffer (first
//! use, or a larger shape than any seen before on this thread), a
//! thread-local counter ticks. [`grow_events`] reads the current
//! thread's count, so a test can run one warm-up pass, snapshot the
//! counter, run the workload again, and assert the delta is zero — the
//! "zero per-pull heap allocations" acceptance check. The counter is
//! thread-local on purpose: concurrently running tests (or other pool
//! workers) cannot pollute the reading.

use std::cell::{Cell, RefCell};
use std::ops::{Deref, DerefMut};

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
    static GROWS: Cell<u64> = const { Cell::new(0) };
}

#[derive(Default)]
struct Pool {
    f32s: Vec<Vec<f32>>,
    f64s: Vec<Vec<f64>>,
    idxs: Vec<Vec<usize>>,
    u8s: Vec<Vec<u8>>,
    i8s: Vec<Vec<i8>>,
}

/// Arena growths observed by the *current thread* so far (monotone).
pub fn grow_events() -> u64 {
    GROWS.with(|g| g.get())
}

fn note_grow() {
    GROWS.with(|g| g.set(g.get() + 1));
}

macro_rules! buf_kind {
    ($guard:ident, $take:ident, $elem:ty, $field:ident, $zero:expr) => {
        /// RAII scratch buffer: derefs to its `Vec`, returns to the
        /// current thread's pool (capacity kept) on drop.
        pub struct $guard {
            buf: Vec<$elem>,
        }

        impl Deref for $guard {
            type Target = Vec<$elem>;
            fn deref(&self) -> &Vec<$elem> {
                &self.buf
            }
        }

        impl DerefMut for $guard {
            fn deref_mut(&mut self) -> &mut Vec<$elem> {
                &mut self.buf
            }
        }

        impl Drop for $guard {
            fn drop(&mut self) {
                let buf = std::mem::take(&mut self.buf);
                POOL.with(|p| p.borrow_mut().$field.push(buf));
            }
        }

        /// Borrow a zero-filled buffer of exactly `len` elements from the
        /// current thread's pool.
        pub fn $take(len: usize) -> $guard {
            let mut buf = POOL.with(|p| p.borrow_mut().$field.pop()).unwrap_or_default();
            if buf.capacity() < len {
                note_grow();
            }
            buf.clear();
            buf.resize(len, $zero);
            $guard { buf }
        }
    };
}

buf_kind!(F32Buf, f32_buf, f32, f32s, 0.0f32);
buf_kind!(F64Buf, f64_buf, f64, f64s, 0.0f64);
buf_kind!(IdxBuf, idx_buf, usize, idxs, 0usize);
buf_kind!(U8Buf, u8_buf, u8, u8s, 0u8);
buf_kind!(I8Buf, i8_buf, i8, i8s, 0i8);

/// An identity index buffer `[0, 1, …, n)` from the arena — the "all
/// rows" argument of the batched hooks.
pub fn iota(n: usize) -> IdxBuf {
    let mut idx = idx_buf(n);
    for (i, slot) in idx.iter_mut().enumerate() {
        *slot = i;
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_reused_and_grows_are_counted() {
        // Isolate from other tests on this thread: measure deltas only.
        let g0 = grow_events();
        {
            let mut a = f32_buf(128);
            a[0] = 1.0;
            assert_eq!(a.len(), 128);
        }
        let after_warm = grow_events();
        assert!(after_warm > g0, "first borrow must grow");
        for _ in 0..10 {
            let b = f32_buf(128);
            assert!(b.iter().all(|&v| v == 0.0), "reused buffer must be zeroed");
        }
        assert_eq!(grow_events(), after_warm, "steady state must not grow");
        // A larger request grows once, then is steady again.
        drop(f32_buf(4096));
        let after_big = grow_events();
        assert!(after_big > after_warm);
        drop(f32_buf(4096));
        assert_eq!(grow_events(), after_big);
    }

    #[test]
    fn nested_borrows_get_distinct_buffers() {
        let mut a = f64_buf(8);
        let mut b = f64_buf(8);
        a[0] = 1.0;
        b[0] = 2.0;
        assert_ne!(a[0], b[0]);
        let mut idx = idx_buf(3);
        idx[2] = 7;
        assert_eq!(&**idx, &[0, 0, 7]);
        let id = iota(4);
        assert_eq!(&**id, &[0, 1, 2, 3]);
        let mut u = u8_buf(3);
        let mut w = i8_buf(3);
        u[1] = 255;
        w[1] = -128;
        assert_eq!(&**u, &[0, 255, 0]);
        assert_eq!(&**w, &[0, -128, 0]);
    }
}
