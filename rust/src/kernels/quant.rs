//! Fused quantized-domain element kernels.
//!
//! `store/codec.rs` defines the chunk *formats*; this module owns the
//! per-element arithmetic so batched readers can evaluate encoded bytes
//! in place — header algebra applied once per chunk run, element decode
//! fused into the consuming reduction — instead of materializing a
//! `Vec<f32>` per chunk. Every kernel computes the exact expression the
//! full-chunk decode uses, so a fused read is bit-identical to
//! decode-then-read (`store/codec.rs` delegates its f16 conversion here
//! to keep the two paths one implementation).
//!
//! Chunk layouts (shared with the codec):
//!
//! | codec | header | payload |
//! |---|---|---|
//! | `F32` | — | `4·len` bytes LE f32 |
//! | `F16` | — | `2·len` bytes LE u16 |
//! | `I8`  | `min: f32 LE` + `scale: f64 LE` (12 bytes) | `len` bytes u8 |

/// The affine I8 chunk header, parsed once per chunk run (the
/// "scale/zero-point algebra once per chunk" of the fused path).
#[derive(Clone, Copy, Debug)]
pub struct I8Header {
    /// Chunk minimum, widened to f64 exactly as the decoder does.
    pub min: f64,
    /// Quantization step `(max − min) / 255` (0 for constant chunks).
    pub scale: f64,
}

impl I8Header {
    /// Decode one u8 code — the codec's exact decode expression
    /// (`(min + scale·u)` in f64, cast to f32).
    #[inline]
    pub fn decode(&self, code: u8) -> f32 {
        (self.min + self.scale * code as f64) as f32
    }
}

/// Parse the 12-byte I8 chunk header.
#[inline]
pub fn i8_header(bytes: &[u8]) -> I8Header {
    I8Header {
        min: f32::from_le_bytes(bytes[0..4].try_into().unwrap()) as f64,
        scale: f64::from_le_bytes(bytes[4..12].try_into().unwrap()),
    }
}

/// The u8 payload of an I8 chunk.
#[inline]
pub fn i8_payload(bytes: &[u8]) -> &[u8] {
    &bytes[12..]
}

/// Fused-decode element `k` of an I8 payload — the codec's exact decode
/// expression, one element at a time.
#[inline]
pub fn i8_at(h: &I8Header, payload: &[u8], k: usize) -> f32 {
    h.decode(payload[k])
}

/// Quantize per-column f64 weights onto the i8 grid for the
/// integer-domain dot: `out[c] = round(w[c] / W)` clamped to
/// `[-127, 127]`, where the returned step `W = max|w| / 127`. All-zero
/// (or empty) weights return `W = 0` with a zeroed grid — the caller
/// short-circuits to the affine base term. Non-finite weights saturate
/// through Rust's defined float→int `as` cast (NaN → 0), so a poisoned
/// query degrades, never UB.
///
/// This is the *documented I8 semantics change* of the integer-domain
/// path: downstream dots become `base + W·Σ u·out[c]`, whose rounding
/// differs from the per-element f32 decode chain. The absolute error of
/// the weighted sum is bounded by `(W/2)·Σ u_c` (each weight moves by at
/// most `W/2`, each code is at most 255).
pub fn quantize_weights(w: &[f64], out: &mut [i8]) -> f64 {
    debug_assert_eq!(w.len(), out.len());
    let mut max_abs = 0f64;
    for &v in w {
        // NaN fails the comparison and is skipped (treated as 0 below).
        if v.abs() > max_abs {
            max_abs = v.abs();
        }
    }
    if max_abs == 0.0 || !max_abs.is_finite() {
        out.fill(0);
        return if max_abs == 0.0 { 0.0 } else { f64::INFINITY };
    }
    let step = max_abs / 127.0;
    for (slot, &v) in out.iter_mut().zip(w) {
        *slot = (v / step).round().clamp(-127.0, 127.0) as i8;
    }
    step
}

/// Element `k` of an F32 chunk (raw little-endian bytes).
#[inline]
pub fn f32_at(bytes: &[u8], k: usize) -> f32 {
    f32::from_le_bytes(bytes[4 * k..4 * k + 4].try_into().unwrap())
}

/// Fused-decode element `k` of an F16 chunk.
#[inline]
pub fn f16_at(bytes: &[u8], k: usize) -> f32 {
    f16_to_f32(u16::from_le_bytes(bytes[2 * k..2 * k + 2].try_into().unwrap()))
}

/// `f32` → IEEE binary16 bits, round-to-nearest (carries propagate into
/// the exponent naturally because the binary16 layout is contiguous).
pub fn f32_to_f16(v: f32) -> u16 {
    let x = v.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp32 = (x >> 23) & 0xff;
    let mant = x & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf / NaN (keep NaN-ness in the top mantissa bit).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let exp = exp32 as i32 - 127 + 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflow → ±0
        }
        // Subnormal half: shift the (implicit-bit) mantissa into place.
        let m = mant | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half = m >> shift;
        let round = (m >> (shift - 1)) & 1;
        return sign | (half + round) as u16;
    }
    let half = ((exp as u32) << 10) | (mant >> 13);
    let round = (mant >> 12) & 1;
    sign | (half + round) as u16
}

/// IEEE binary16 bits → `f32` (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // Subnormal half: normalize into an f32 exponent.
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // ±inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::Codec;
    use crate::util::rng::Rng;

    #[test]
    fn f16_round_trip_is_exhaustive_over_all_bit_patterns() {
        // Every one of the 65 536 binary16 patterns: decode to f32 and
        // re-encode. Zeros, subnormals, normals, and infinities are
        // exactly representable in f32, so the round trip must be the
        // identity on their bits; NaNs canonicalize to the quiet
        // pattern, so for them the *class* and sign must survive.
        let (mut nans, mut subnormals, mut infs) = (0u32, 0u32, 0u32);
        for h in 0..=u16::MAX {
            let f = f16_to_f32(h);
            let (exp, mant) = ((h >> 10) & 0x1f, h & 0x3ff);
            if exp == 0x1f && mant != 0 {
                nans += 1;
                assert!(f.is_nan(), "{h:#06x} decoded to non-NaN {f}");
                let back = f32_to_f16(f);
                assert_eq!(back & 0x8000, h & 0x8000, "{h:#06x}: NaN sign lost");
                assert_eq!(back & 0x7c00, 0x7c00, "{h:#06x}: NaN exponent lost");
                assert_ne!(back & 0x3ff, 0, "{h:#06x}: NaN collapsed to inf");
                continue;
            }
            if exp == 0x1f {
                infs += 1;
                assert!(f.is_infinite(), "{h:#06x}");
            }
            if exp == 0 && mant != 0 {
                subnormals += 1;
                assert!(f != 0.0 && f.abs() < 6.2e-5, "{h:#06x} decoded to {f}");
            }
            if exp == 0 && mant == 0 {
                assert_eq!(f.to_bits(), (h as u32) << 16, "{h:#06x}: wrong zero");
            }
            let back = f32_to_f16(f);
            assert_eq!(back, h, "{h:#06x} → {f} → {back:#06x}");
        }
        assert_eq!((nans, subnormals, infs), (2 * 1023, 2 * 1023, 2));
    }

    #[test]
    fn weight_quantization_grid_and_step() {
        // Max-magnitude weight lands exactly on ±127; zeros stay zero.
        let w = [1.0f64, -0.5, 0.0, 0.25];
        let mut grid = [0i8; 4];
        let step = quantize_weights(&w, &mut grid);
        assert_eq!(step, 1.0 / 127.0);
        assert_eq!(grid, [127, -64, 0, 32]);
        // Reconstruction error per weight is within step/2.
        for (&v, &g) in w.iter().zip(&grid) {
            assert!((v - step * g as f64).abs() <= step / 2.0 + 1e-15);
        }
        // All-zero weights short-circuit.
        let step = quantize_weights(&[0.0, -0.0], &mut grid[..2]);
        assert_eq!(step, 0.0);
        assert_eq!(&grid[..2], &[0, 0]);
        // Empty input is a no-op, not a panic.
        assert_eq!(quantize_weights(&[], &mut []), 0.0);
        // Non-finite weights saturate deterministically instead of UB.
        let step = quantize_weights(&[f64::INFINITY, 1.0], &mut grid[..2]);
        assert!(step.is_infinite());
        assert_eq!(&grid[..2], &[0, 0]);
    }

    #[test]
    fn fused_element_kernels_match_full_chunk_decode_bitwise() {
        let mut rng = Rng::new(0xF05E);
        for len in [1usize, 7, 16, 100, 255] {
            let vals: Vec<f32> = (0..len)
                .map(|_| (rng.normal() * 10.0f64.powi(rng.below(5) as i32 - 2)) as f32)
                .collect();
            for codec in [Codec::F32, Codec::F16, Codec::I8] {
                let mut bytes = Vec::new();
                codec.encode(&vals, &mut bytes);
                let mut decoded = Vec::new();
                codec.decode(&bytes, len, &mut decoded);
                for k in 0..len {
                    let fused = match codec {
                        Codec::F32 => f32_at(&bytes, k),
                        Codec::F16 => f16_at(&bytes, k),
                        Codec::I8 => i8_at(&i8_header(&bytes), i8_payload(&bytes), k),
                    };
                    assert_eq!(
                        fused.to_bits(),
                        decoded[k].to_bits(),
                        "{codec:?} len {len} element {k}: {fused} vs {}",
                        decoded[k]
                    );
                }
            }
        }
    }
}
