//! Cross-module integration tests: each one exercises several layers of
//! the stack together (datasets → algorithms → metrics; artifacts → PJRT
//! → coordinator), i.e. the seams unit tests can't see.

use std::sync::Arc;

use adaptive_sampling::coordinator::{Backend, MipsServer, ServerConfig};
use adaptive_sampling::data::distance::Metric;
use adaptive_sampling::data::synthetic::{lowrank_like, mnist_like_d, scrna_like};
use adaptive_sampling::data::tabular::{covtype_like, mnist_classification};
use adaptive_sampling::data::trees::TreePointSet;
use adaptive_sampling::data::{PointSet, VecPointSet};
use adaptive_sampling::forest::ensemble::{Forest, ForestConfig, ForestKind};
use adaptive_sampling::forest::tree::Solver;
use adaptive_sampling::kmedoids::banditpam::{bandit_pam, BanditPamConfig};
use adaptive_sampling::kmedoids::pam::{pam, SwapMode};
use adaptive_sampling::kmedoids::KmConfig;
use adaptive_sampling::metrics::OpCounter;
use adaptive_sampling::mips::banditmips::{bandit_mips, BanditMipsConfig};
use adaptive_sampling::mips::naive_mips;
use adaptive_sampling::runtime::service::PjrtHandle;
use adaptive_sampling::runtime::ArtifactStore;
use adaptive_sampling::util::rng::Rng;

/// BanditPAM over *program trees with edit distance* — the exotic-metric
/// path (data::trees + kmedoids + bandit engine together).
#[test]
fn banditpam_clusters_program_trees() {
    let ps = TreePointSet::hoc4_like(160, 5);
    let cfg = KmConfig::new(2);
    let exact = pam(&ps, &cfg, SwapMode::FastPam1);
    let mut bcfg = BanditPamConfig::new(2);
    bcfg.km = cfg;
    let bandit = bandit_pam(&ps, &bcfg);
    assert!(
        bandit.loss <= exact.loss * 1.05,
        "bandit {} vs exact {}",
        bandit.loss,
        exact.loss
    );
}

/// The three chapters compose: cluster cells, train a forest on the
/// cluster labels, then use MIPS to find each medoid's nearest atoms.
#[test]
fn chapters_compose_end_to_end() {
    // Ch2: cluster scRNA-like cells.
    let mat = scrna_like(300, 64, 9);
    let ps = VecPointSet::new(mat.clone(), Metric::L1);
    let km = bandit_pam(&ps, &BanditPamConfig::new(4));
    assert_eq!(km.medoids.len(), 4);

    // Labels from cluster assignment → Ch3 forest learns them.
    let cache = adaptive_sampling::kmedoids::MedoidCache::compute(&ps, &km.medoids);
    let labels: Vec<f32> = cache.nearest.iter().map(|&m| m as f32).collect();
    let ds = adaptive_sampling::data::LabeledDataset { x: mat.clone(), y: labels, n_classes: 4 };
    let c = OpCounter::new();
    let mut fcfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
    fcfg.n_trees = 5;
    fcfg.max_depth = 6;
    let forest = Forest::fit(&ds, &fcfg, &c);
    let acc = forest.accuracy(&ds);
    assert!(acc > 0.7, "forest can't learn cluster structure: {acc}");

    // Ch4: medoid rows as queries; the medoid itself must be the argmax
    // of inner product... over normalized rows that's its own row.
    let c = OpCounter::new();
    let q = mat.row(km.medoids[0]);
    let ans = bandit_mips(&mat, q, &BanditMipsConfig::default(), &c);
    let truth = naive_mips(&mat, q, 1, &c);
    assert_eq!(ans.atoms[0], truth[0]);
}

/// Determinism: identical seeds give identical medoids / splits / atoms.
#[test]
fn everything_is_deterministic_given_seed() {
    let run = || {
        let ps = VecPointSet::new(mnist_like_d(200, 32, 7), Metric::L2);
        let km = bandit_pam(&ps, &BanditPamConfig::new(3));
        let ds = mnist_classification(1000, 32, 7);
        let c = OpCounter::new();
        let f = Forest::fit(&ds, &ForestConfig::new(ForestKind::RandomForest, Solver::mab()), &c);
        let (atoms, queries) = adaptive_sampling::data::synthetic::normal_custom(50, 1000, 1, 7);
        let c2 = OpCounter::new();
        let m = bandit_mips(&atoms, queries.row(0), &BanditMipsConfig::default(), &c2);
        (km.medoids, c.get(), m.atoms, m.samples)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
}

/// Full PJRT round trip through the artifact store: Python-authored
/// kernels must agree with the native Rust implementations numerically.
#[test]
fn pjrt_and_native_agree_on_swap_pulls() {
    let dir = ArtifactStore::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let store = ArtifactStore::load(&dir).unwrap();
    let meta = store.meta("bpam_swap_t64_r256_d784").unwrap().clone();
    let (t, d) = (meta.params[0][0], meta.params[0][1]);
    let r = meta.params[1][0];
    let mut rng = Rng::new(17);
    let cand: Vec<f32> = (0..t * d).map(|_| rng.f32()).collect();
    let refs: Vec<f32> = (0..r * d).map(|_| rng.f32()).collect();
    let d1: Vec<f32> = (0..r).map(|_| rng.f32() * 3.0).collect();
    let d2: Vec<f32> = d1.iter().map(|&v| v + 1.0).collect();
    let mine: Vec<f32> = (0..r).map(|i| (i % 2) as f32).collect();
    let out = store
        .exec_f32("bpam_swap_t64_r256_d784", &[&cand, &refs, &d1, &d2, &mine])
        .unwrap();
    // Native check: g = min(dist, w) − d1, w = mine ? d2 : d1.
    for &(ti, ri) in &[(0usize, 0usize), (3, 33), (63, 255)] {
        let dist = adaptive_sampling::data::distance::l2(
            &cand[ti * d..(ti + 1) * d],
            &refs[ri * d..(ri + 1) * d],
        ) as f32;
        let w = if mine[ri] > 0.5 { d2[ri] } else { d1[ri] };
        let want = dist.min(w) - d1[ri];
        let got = out[0][ti * r + ri];
        assert!((got - want).abs() < 1e-2, "({ti},{ri}): {got} vs {want}");
    }
}

/// The serving coordinator over the PJRT exact backend returns true
/// argmaxes (the artifact path, not the native one).
#[test]
fn pjrt_exact_backend_serves_correctly() {
    let dir = ArtifactStore::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let handle = PjrtHandle::start(&dir).unwrap();
    let atoms = Arc::new(lowrank_like(512, 1024, 10, 3));
    let cfg = ServerConfig { workers: 2, max_batch: 4, ..Default::default() };
    let backend = Backend::PjrtExact { store: handle, entry: "mips_scores_n512_d1024".into() };
    let server = MipsServer::start(atoms.clone(), cfg, backend);
    let mut rng = Rng::new(31);
    let mut correct = 0;
    let total = 8;
    for _ in 0..total {
        let q: Vec<f32> = (0..atoms.d).map(|_| rng.f32() * 5.0).collect();
        let rx = server.submit(q.clone());
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        let c = OpCounter::new();
        let truth = naive_mips(&atoms, &q, 1, &c);
        if resp.top_atoms.first() == truth.first() {
            correct += 1;
        }
    }
    assert_eq!(correct, total, "PJRT exact backend must be exact");
    server.shutdown();
}

/// Fixed-budget training respects the budget across ensemble kinds and
/// both solvers (integration of tree budget + forest loop + counters).
#[test]
fn budgets_respected_across_kinds() {
    let ds = covtype_like(8_000, 21);
    for kind in [ForestKind::RandomForest, ForestKind::ExtraTrees, ForestKind::RandomPatches] {
        for solver in [Solver::Exact, Solver::mab()] {
            let budget = 8_000u64 * 8;
            let c = OpCounter::new();
            let mut cfg = ForestConfig::new(kind, solver);
            cfg.n_trees = 50;
            cfg.budget = Some(budget);
            let _ = Forest::fit(&ds, &cfg, &c);
            // one node's full scan of overshoot allowed (checked-before,
            // spent-during semantics)
            assert!(
                c.get() <= budget + 8_000 * 8,
                "{kind:?}/{solver:?}: {} over budget {budget}",
                c.get()
            );
        }
    }
}

/// Op counters are the single source of truth: KmResult's dist_calls must
/// equal the counter delta.
#[test]
fn counters_and_results_agree() {
    let ps = VecPointSet::new(mnist_like_d(150, 16, 3), Metric::L2);
    ps.counter().reset();
    let r = bandit_pam(&ps, &BanditPamConfig::new(3));
    assert_eq!(r.dist_calls, ps.counter().get());
}

/// The tentpole contract across all three chapter solvers at once: with a
/// fixed seed, running every solver on the shared-pool parallel engine
/// (threads = 0 → one shard per pool worker) reproduces the sequential
/// results bit-for-bit, including the paper's sample-complexity counters.
#[test]
fn sharded_engine_bit_identical_across_all_solvers() {
    let run = |threads: usize| {
        // Ch2: BanditPAM.
        let ps = VecPointSet::new(mnist_like_d(160, 24, 7), Metric::L2);
        let mut kcfg = BanditPamConfig::new(3);
        kcfg.threads = threads;
        let km = bandit_pam(&ps, &kcfg);

        // Ch3: MABSplit forest.
        let ds = mnist_classification(1200, 32, 7);
        let c = OpCounter::new();
        let mut fcfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
        fcfg.threads = threads;
        let f = Forest::fit(&ds, &fcfg, &c);

        // Ch4: BanditMIPS.
        let (atoms, queries) = adaptive_sampling::data::synthetic::normal_custom(60, 2_000, 1, 7);
        let c2 = OpCounter::new();
        let mut mcfg = BanditMipsConfig::default();
        mcfg.threads = threads;
        let m = bandit_mips(&atoms, queries.row(0), &mcfg, &c2);

        (
            km.medoids,
            km.loss.to_bits(),
            km.dist_calls,
            c.get(),
            f.trees.iter().map(|t| t.nodes_split).collect::<Vec<_>>(),
            m.atoms,
            m.samples,
        )
    };
    let seq = run(1);
    assert_eq!(run(0), seq, "shared-pool engine diverged from sequential");
    assert_eq!(run(3), seq, "3-shard engine diverged from sequential");
}
