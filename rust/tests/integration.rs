//! Cross-module integration tests: each one exercises several layers of
//! the stack together (datasets → algorithms → metrics; artifacts → PJRT
//! → coordinator), i.e. the seams unit tests can't see.

mod common;

use std::sync::Arc;

use adaptive_sampling::coordinator::{Backend, MipsServer, ServerConfig};
use adaptive_sampling::data::distance::Metric;
use adaptive_sampling::data::synthetic::{lowrank_like, mnist_like_d, scrna_like};
use adaptive_sampling::data::tabular::{covtype_like, mnist_classification};
use adaptive_sampling::data::trees::TreePointSet;
use adaptive_sampling::data::{PointSet, VecPointSet};
use adaptive_sampling::forest::ensemble::{Forest, ForestConfig, ForestKind};
use adaptive_sampling::forest::tree::Solver;
use adaptive_sampling::kmedoids::banditpam::{bandit_pam, BanditPamConfig};
use adaptive_sampling::kmedoids::pam::{pam, SwapMode};
use adaptive_sampling::kmedoids::KmConfig;
use adaptive_sampling::metrics::OpCounter;
use adaptive_sampling::mips::banditmips::{bandit_mips, BanditMipsConfig};
use adaptive_sampling::mips::naive_mips;
use adaptive_sampling::forest::split::TrainSet;
use adaptive_sampling::runtime::service::PjrtHandle;
use adaptive_sampling::runtime::ArtifactStore;
use adaptive_sampling::store::{ColumnStore, DatasetView, StoreOptions, ViewPointSet};
use adaptive_sampling::util::proptest::prop_check;
use adaptive_sampling::util::rng::Rng;

/// BanditPAM over *program trees with edit distance* — the exotic-metric
/// path (data::trees + kmedoids + bandit engine together).
#[test]
fn banditpam_clusters_program_trees() {
    let ps = TreePointSet::hoc4_like(160, 5);
    let cfg = KmConfig::new(2);
    let exact = pam(&ps, &cfg, SwapMode::FastPam1);
    let mut bcfg = BanditPamConfig::new(2);
    bcfg.km = cfg;
    let bandit = bandit_pam(&ps, &bcfg);
    assert!(
        bandit.loss <= exact.loss * 1.05,
        "bandit {} vs exact {}",
        bandit.loss,
        exact.loss
    );
}

/// The three chapters compose: cluster cells, train a forest on the
/// cluster labels, then use MIPS to find each medoid's nearest atoms.
#[test]
fn chapters_compose_end_to_end() {
    // Ch2: cluster scRNA-like cells.
    let mat = scrna_like(300, 64, 9);
    let ps = VecPointSet::new(mat.clone(), Metric::L1);
    let km = bandit_pam(&ps, &BanditPamConfig::new(4));
    assert_eq!(km.medoids.len(), 4);

    // Labels from cluster assignment → Ch3 forest learns them.
    let cache = adaptive_sampling::kmedoids::MedoidCache::compute(&ps, &km.medoids);
    let labels: Vec<f32> = cache.nearest.iter().map(|&m| m as f32).collect();
    let ds = adaptive_sampling::data::LabeledDataset { x: mat.clone(), y: labels, n_classes: 4 };
    let c = OpCounter::new();
    let mut fcfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
    fcfg.n_trees = 5;
    fcfg.max_depth = 6;
    let forest = Forest::fit(&ds, &fcfg, &c);
    let acc = forest.accuracy(&ds);
    assert!(acc > 0.7, "forest can't learn cluster structure: {acc}");

    // Ch4: medoid rows as queries; the medoid itself must be the argmax
    // of inner product... over normalized rows that's its own row.
    let c = OpCounter::new();
    let q = mat.row(km.medoids[0]);
    let ans = bandit_mips(&mat, q, &BanditMipsConfig::default(), &c);
    let truth = naive_mips(&mat, q, 1, &c);
    assert_eq!(ans.atoms[0], truth[0]);
}

/// Determinism: identical seeds give identical medoids / splits / atoms.
#[test]
fn everything_is_deterministic_given_seed() {
    let run = || {
        let ps = VecPointSet::new(mnist_like_d(200, 32, 7), Metric::L2);
        let km = bandit_pam(&ps, &BanditPamConfig::new(3));
        let ds = mnist_classification(1000, 32, 7);
        let c = OpCounter::new();
        let f = Forest::fit(&ds, &ForestConfig::new(ForestKind::RandomForest, Solver::mab()), &c);
        let (atoms, queries) = adaptive_sampling::data::synthetic::normal_custom(50, 1000, 1, 7);
        let c2 = OpCounter::new();
        let m = bandit_mips(&atoms, queries.row(0), &BanditMipsConfig::default(), &c2);
        (km.medoids, c.get(), m.atoms, m.samples)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
}

/// Full PJRT round trip through the artifact store: Python-authored
/// kernels must agree with the native Rust implementations numerically.
#[test]
fn pjrt_and_native_agree_on_swap_pulls() {
    let dir = ArtifactStore::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let store = ArtifactStore::load(&dir).unwrap();
    let meta = store.meta("bpam_swap_t64_r256_d784").unwrap().clone();
    let (t, d) = (meta.params[0][0], meta.params[0][1]);
    let r = meta.params[1][0];
    let mut rng = Rng::new(17);
    let cand: Vec<f32> = (0..t * d).map(|_| rng.f32()).collect();
    let refs: Vec<f32> = (0..r * d).map(|_| rng.f32()).collect();
    let d1: Vec<f32> = (0..r).map(|_| rng.f32() * 3.0).collect();
    let d2: Vec<f32> = d1.iter().map(|&v| v + 1.0).collect();
    let mine: Vec<f32> = (0..r).map(|i| (i % 2) as f32).collect();
    let out = store
        .exec_f32("bpam_swap_t64_r256_d784", &[&cand, &refs, &d1, &d2, &mine])
        .unwrap();
    // Native check: g = min(dist, w) − d1, w = mine ? d2 : d1.
    for &(ti, ri) in &[(0usize, 0usize), (3, 33), (63, 255)] {
        let dist = adaptive_sampling::data::distance::l2(
            &cand[ti * d..(ti + 1) * d],
            &refs[ri * d..(ri + 1) * d],
        ) as f32;
        let w = if mine[ri] > 0.5 { d2[ri] } else { d1[ri] };
        let want = dist.min(w) - d1[ri];
        let got = out[0][ti * r + ri];
        assert!((got - want).abs() < 1e-2, "({ti},{ri}): {got} vs {want}");
    }
}

/// The serving coordinator over the PJRT exact backend returns true
/// argmaxes (the artifact path, not the native one).
#[test]
fn pjrt_exact_backend_serves_correctly() {
    let dir = ArtifactStore::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let handle = PjrtHandle::start(&dir).unwrap();
    let atoms = Arc::new(lowrank_like(512, 1024, 10, 3));
    let cfg = ServerConfig { workers: 2, max_batch: 4, ..Default::default() };
    let backend = Backend::PjrtExact { store: handle, entry: "mips_scores_n512_d1024".into() };
    let server = MipsServer::start(atoms.clone(), cfg, backend);
    let mut rng = Rng::new(31);
    let mut correct = 0;
    let total = 8;
    for _ in 0..total {
        let q: Vec<f32> = (0..atoms.d).map(|_| rng.f32() * 5.0).collect();
        let rx = server.submit(q.clone());
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        let c = OpCounter::new();
        let truth = naive_mips(&*atoms, &q, 1, &c);
        if resp.top_atoms.first() == truth.first() {
            correct += 1;
        }
    }
    assert_eq!(correct, total, "PJRT exact backend must be exact");
    server.shutdown();
}

/// Fixed-budget training respects the budget across ensemble kinds and
/// both solvers (integration of tree budget + forest loop + counters).
#[test]
fn budgets_respected_across_kinds() {
    let ds = covtype_like(8_000, 21);
    for kind in [ForestKind::RandomForest, ForestKind::ExtraTrees, ForestKind::RandomPatches] {
        for solver in [Solver::Exact, Solver::mab()] {
            let budget = 8_000u64 * 8;
            let c = OpCounter::new();
            let mut cfg = ForestConfig::new(kind, solver);
            cfg.n_trees = 50;
            cfg.budget = Some(budget);
            let _ = Forest::fit(&ds, &cfg, &c);
            // one node's full scan of overshoot allowed (checked-before,
            // spent-during semantics)
            assert!(
                c.get() <= budget + 8_000 * 8,
                "{kind:?}/{solver:?}: {} over budget {budget}",
                c.get()
            );
        }
    }
}

/// Op counters are the single source of truth: KmResult's dist_calls must
/// equal the counter delta.
#[test]
fn counters_and_results_agree() {
    let ps = VecPointSet::new(mnist_like_d(150, 16, 3), Metric::L2);
    ps.counter().reset();
    let r = bandit_pam(&ps, &BanditPamConfig::new(3));
    assert_eq!(r.dist_calls, ps.counter().get());
}

/// The tentpole contract across all three chapter solvers at once: with a
/// fixed seed, running every solver on the shared-pool parallel engine
/// (threads = 0 → one shard per pool worker) reproduces the sequential
/// results bit-for-bit, including the paper's sample-complexity counters.
#[test]
fn sharded_engine_bit_identical_across_all_solvers() {
    let run = |threads: usize| {
        // Ch2: BanditPAM.
        let ps = VecPointSet::new(mnist_like_d(160, 24, 7), Metric::L2);
        let mut kcfg = BanditPamConfig::new(3);
        kcfg.threads = threads;
        let km = bandit_pam(&ps, &kcfg);

        // Ch3: MABSplit forest.
        let ds = mnist_classification(1200, 32, 7);
        let c = OpCounter::new();
        let mut fcfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
        fcfg.threads = threads;
        let f = Forest::fit(&ds, &fcfg, &c);

        // Ch4: BanditMIPS.
        let (atoms, queries) = adaptive_sampling::data::synthetic::normal_custom(60, 2_000, 1, 7);
        let c2 = OpCounter::new();
        let mut mcfg = BanditMipsConfig::default();
        mcfg.threads = threads;
        let m = bandit_mips(&atoms, queries.row(0), &mcfg, &c2);

        (
            km.medoids,
            km.loss.to_bits(),
            km.dist_calls,
            c.get(),
            f.trees.iter().map(|t| t.nodes_split).collect::<Vec<_>>(),
            m.atoms,
            m.samples,
        )
    };
    let seq = run(1);
    assert_eq!(run(0), seq, "shared-pool engine diverged from sequential");
    assert_eq!(run(3), seq, "3-shard engine diverged from sequential");
}

/// The store leg of the tentpole contract, per solver: for a fixed seed,
/// BanditPAM / MABSplit / BanditMIPS return bit-identical results *and
/// op-counter totals* on a dense `Matrix` and on a `ColumnStore(F32)`,
/// at every thread count in {1, 2, 4, 8}.
#[test]
fn column_store_f32_bit_identical_across_solvers_and_threads() {
    // Ch2: BanditPAM over VecPointSet(Matrix) vs ViewPointSet(ColumnStore).
    let pts = mnist_like_d(120, 24, 7);
    let pts_cs = Arc::new(
        ColumnStore::from_matrix(&pts, &StoreOptions { rows_per_chunk: 32, ..Default::default() })
            .unwrap(),
    );
    // Ch3: one MABSplit forest.
    let ds = mnist_classification(1_000, 32, 7);
    let ds_cs = Arc::new(ColumnStore::from_matrix(&ds.x, &StoreOptions::default()).unwrap());
    // Ch4: BanditMIPS.
    let (atoms, queries) = adaptive_sampling::data::synthetic::normal_custom(60, 2_000, 1, 7);
    let atoms_cs = Arc::new(
        ColumnStore::from_matrix(
            &atoms,
            &StoreOptions { rows_per_chunk: 256, ..Default::default() },
        )
        .unwrap(),
    );

    type Fingerprint = (Vec<usize>, u64, u64, u64, Vec<usize>, Vec<usize>, u64, u64);
    let run = |threads: usize, columnar: bool| -> Fingerprint {
        let km = {
            let mut kcfg = BanditPamConfig::new(3);
            kcfg.threads = threads;
            if columnar {
                let ps = ViewPointSet::new(pts_cs.clone(), Metric::L2);
                bandit_pam(&ps, &kcfg)
            } else {
                let ps = VecPointSet::new(pts.clone(), Metric::L2);
                bandit_pam(&ps, &kcfg)
            }
        };
        let (insertions, splits) = {
            let c = OpCounter::new();
            let mut fcfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
            fcfg.n_trees = 2;
            fcfg.threads = threads;
            let ts = if columnar {
                TrainSet { x: &*ds_cs, y: &ds.y, n_classes: ds.n_classes }
            } else {
                TrainSet::of(&ds)
            };
            let f = Forest::fit_view(&ts, &fcfg, &c);
            (c.get(), f.trees.iter().map(|t| t.nodes_split).collect::<Vec<_>>())
        };
        let (m_atoms, m_samples, m_ops) = {
            let c = OpCounter::new();
            let mut mcfg = BanditMipsConfig::default();
            mcfg.threads = threads;
            let ans = if columnar {
                bandit_mips(&*atoms_cs, queries.row(0), &mcfg, &c)
            } else {
                bandit_mips(&atoms, queries.row(0), &mcfg, &c)
            };
            (ans.atoms, ans.samples, c.get())
        };
        (
            km.medoids,
            km.loss.to_bits(),
            km.dist_calls,
            insertions,
            splits,
            m_atoms,
            m_samples,
            m_ops,
        )
    };

    let reference = run(1, false);
    for threads in [1usize, 2, 4, 8] {
        assert_eq!(run(threads, false), reference, "matrix path, threads={threads}");
        assert_eq!(run(threads, true), reference, "column store, threads={threads}");
    }
}

/// Property form of the storage contract: random shapes/seeds, BanditMIPS
/// on Matrix vs ColumnStore(F32) must match answers and op totals at
/// several thread counts.
#[test]
fn prop_store_and_matrix_agree_for_random_mips_instances() {
    prop_check(
        0x57E,
        8,
        |r| (5 + r.below(40), 100 + r.below(900), r.next_u64()),
        |&(n, d, seed)| {
            // Shared fixture generator (testkit) instead of an inline one.
            let atoms = common::gaussian(n, d, seed);
            let mut rng = Rng::new(seed ^ 0x51);
            let q: Vec<f32> = (0..d).map(|_| (rng.normal() * 2.0) as f32).collect();
            let cs = ColumnStore::from_matrix(
                &atoms,
                &StoreOptions { rows_per_chunk: 64, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let run = |view: &dyn DatasetView, threads: usize| {
                let c = OpCounter::new();
                let cfg = BanditMipsConfig { seed, threads, ..Default::default() };
                let ans = bandit_mips(view, &q, &cfg, &c);
                (ans.atoms, ans.samples, c.get())
            };
            let want = run(&atoms, 1);
            for threads in [1usize, 2, 4, 8] {
                let got = run(&cs, threads);
                if got != want {
                    return Err(format!(
                        "n={n} d={d} threads={threads}: store {got:?} != matrix {want:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Out-of-core acceptance: a solver runs over a ColumnStore whose spill
/// cache budget is far smaller than the dataset, streams chunks from
/// disk, and still reproduces the dense answers bit-for-bit (F32 codec).
#[test]
fn solver_runs_out_of_core_when_budget_is_smaller_than_dataset() {
    let (atoms, queries) = adaptive_sampling::data::synthetic::normal_custom(80, 2_000, 2, 7);
    let raw_bytes = atoms.n * atoms.d * 4; // 640 KB
    let opts = StoreOptions { rows_per_chunk: 128, ..Default::default() }
        .spill_to_temp(raw_bytes / 10);
    let cs = ColumnStore::from_matrix(&atoms, &opts).unwrap();
    assert!(cs.spilled());

    for qi in 0..queries.n {
        let c_dense = OpCounter::new();
        let c_store = OpCounter::new();
        let cfg = BanditMipsConfig::default();
        let dense = bandit_mips(&atoms, queries.row(qi), &cfg, &c_dense);
        let store = bandit_mips(&cs, queries.row(qi), &cfg, &c_store);
        assert_eq!(
            (dense.atoms, dense.samples, c_dense.get()),
            (store.atoms, store.samples, c_store.get()),
            "query {qi} diverged out of core"
        );
    }
    assert!(cs.spill_reads() > 0, "nothing streamed from disk");
    assert!(cs.decode_ops() > 0, "decode cost must be metered");

    // A quantized spilled store still trains a usable forest end to end.
    let ds = mnist_classification(800, 16, 3);
    let q_opts = StoreOptions {
        codec: adaptive_sampling::store::Codec::I8,
        rows_per_chunk: 128,
        ..Default::default()
    }
    .spill_to_temp(8 * 1024);
    let qcs = ColumnStore::from_matrix(&ds.x, &q_opts).unwrap();
    let ts = TrainSet { x: &qcs, y: &ds.y, n_classes: ds.n_classes };
    let c = OpCounter::new();
    let mut fcfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
    fcfg.n_trees = 3;
    let f = Forest::fit_view(&ts, &fcfg, &c);
    let acc = f.accuracy_view(&ts);
    assert!(acc > 0.5, "i8 out-of-core forest accuracy {acc}");
    assert!(qcs.cache_evictions() > 0, "tiny budget must evict");
}
