//! Fault-injection acceptance tests for the `chaos` subsystem and the
//! graceful-degradation contracts it exists to prove:
//!
//! - **No-perturbation**: chaos compiled in but idle (disabled, or armed
//!   with rules that never fire) changes no answer digest and no gated
//!   op count across the smoke-tier scenario registry.
//! - **One-shot sweep**: every registered failpoint site fires exactly
//!   once under a matching operation; no panic escapes a public API, the
//!   typed error (or retry absorption) lands where documented, and
//!   recovery replays the surviving state bit-exact and idempotently.
//! - **Random walk**: `chaos::driver` runs ingest/serve/kill/recover
//!   cycles under a probabilistic schedule and reports zero invariant
//!   violations.
//!
//! Chaos state is process-global (like `obs`), so every test here
//! serializes on [`chaos_lock`].

mod common;

use std::collections::BTreeSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use adaptive_sampling::chaos::{self, driver, FaultKind, Schedule, ScheduleGuard};
use adaptive_sampling::coordinator::{Backend, MipsServer, ServerConfig};
use adaptive_sampling::exec::{Gate, WorkerPool};
use adaptive_sampling::harness::{scenarios_for, Tier};
use adaptive_sampling::metrics::OpCounter;
use adaptive_sampling::mips::banditmips::{bandit_mips_warm, BanditMipsConfig, SampleStrategy};
use adaptive_sampling::store::{ColumnStore, DatasetView, LiveStore, StoreOptions};
use adaptive_sampling::util::rng::Rng;
use common::*;

fn chaos_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

static DIR_SERIAL: AtomicU64 = AtomicU64::new(0);

/// A process-unique scratch data directory under the system temp dir.
fn scratch_dir(tag: &str) -> PathBuf {
    let serial = DIR_SERIAL.fetch_add(1, Ordering::Relaxed);
    let name = format!("as_chaos_{tag}_{}_{serial}", std::process::id());
    std::env::temp_dir().join(name)
}

/// Total fire count across active rules watching `site`.
fn fires(site: &str) -> u64 {
    chaos::report().iter().filter(|r| r.site == site).map(|r| r.fires).sum()
}

const D: usize = 4;
const BATCH: usize = 16;

fn small_opts() -> StoreOptions {
    StoreOptions { rows_per_chunk: 8, ..Default::default() }
}

// Site coverage ledger: each sweep test below exercises the sites it
// names; `every_registered_site_is_swept` asserts the union is exactly
// `chaos::SITES`, so registering a new failpoint without extending the
// sweep fails the suite.
const COMMIT_PATH_SITES: &[&str] = &[
    "persist.segment.write",
    "persist.segment.read",
    "persist.manifest.append",
    "persist.manifest.fsync",
    "live.commit",
];
const MUTATION_SITES: &[&str] = &["live.delete", "live.compact", "persist.manifest.rewrite"];
const SPILL_SITES: &[&str] = &["spill.write", "spill.finish", "spill.read"];
const INGEST_SITES: &[&str] = &["live.ingest"];
const SERVE_SITES: &[&str] = &["serve.query"];
const EXEC_SITES: &[&str] = &["exec.task", "exec.gate.stall"];
const NET_SITES: &[&str] = &["net.accept", "net.shard.rpc"];

#[test]
fn every_registered_site_is_swept() {
    let swept: BTreeSet<&str> = COMMIT_PATH_SITES
        .iter()
        .chain(MUTATION_SITES)
        .chain(SPILL_SITES)
        .chain(INGEST_SITES)
        .chain(SERVE_SITES)
        .chain(EXEC_SITES)
        .chain(NET_SITES)
        .copied()
        .collect();
    let registered: BTreeSet<&str> = chaos::SITES.iter().copied().collect();
    assert_eq!(
        swept, registered,
        "the one-shot sweep must cover exactly the registered failpoint sites"
    );
}

// ---------------------------------------------------------------------
// The no-perturbation contract: with chaos disabled, and with chaos
// armed by rules that never fire, every smoke-tier scenario produces a
// bit-identical CostRecord (same counters, same answer digest). This is
// the license to leave failpoints compiled into release builds.
// ---------------------------------------------------------------------
#[test]
fn idle_chaos_perturbs_no_digest_or_op_count() {
    let _g = chaos_lock();
    chaos::clear();
    let scenarios = scenarios_for(Tier::Smoke);
    assert!(!scenarios.is_empty());
    let off: Vec<_> = scenarios.iter().map(|s| s.run()).collect();

    // Armed but empty: the enabled flag is set, no rule matches anything.
    let _guard = ScheduleGuard::install(Schedule::new(7)).unwrap();
    let armed_empty: Vec<_> = scenarios.iter().map(|s| s.run()).collect();
    drop(_guard);

    // Armed with a never-firing rule on a hot infallible site: hits are
    // counted, the fault never executes.
    let _guard = ScheduleGuard::install(
        Schedule::new(7).one_shot("exec.task", FaultKind::Panic, u64::MAX),
    )
    .unwrap();
    let armed_cold: Vec<_> = scenarios.iter().map(|s| s.run()).collect();
    drop(_guard);

    for ((a, b), c) in off.iter().zip(&armed_empty).zip(&armed_cold) {
        assert_eq!(a, b, "{}: an empty chaos schedule perturbed the cost model", a.scenario);
        assert_eq!(a, c, "{}: a never-firing chaos rule perturbed the cost model", a.scenario);
    }
}

// ---------------------------------------------------------------------
// One-shot sweep, commit path: a single injected fault at each durable
// commit site either (a) is absorbed by the bounded retry (transient
// write/fsync/read-back errors) so the commit still succeeds, or (b)
// surfaces as a typed error with nothing published. Either way the
// store stays usable, shuts down clean, and recovers bit-exact twice.
// ---------------------------------------------------------------------
#[test]
fn commit_path_one_shot_faults_recover_bit_exact() {
    let _g = chaos_lock();
    // (site, kind, absorbed-by-retry)
    let cases: &[(&str, FaultKind, bool)] = &[
        ("persist.segment.write", FaultKind::Error, true),
        ("persist.segment.read", FaultKind::Error, true),
        ("persist.segment.read", FaultKind::Corrupt, false), // corrupt read-back: never retried
        ("persist.manifest.append", FaultKind::Error, true),
        ("persist.manifest.fsync", FaultKind::Error, true),
        ("live.commit", FaultKind::Error, false),
    ];
    for (i, &(site, kind, absorbed)) in cases.iter().enumerate() {
        let dir = scratch_dir("commit_sweep");
        let live = LiveStore::open(D, small_opts(), &dir).unwrap();
        live.commit_batch(&gaussian(BATCH, D, 11)).unwrap();

        let guard =
            ScheduleGuard::install(Schedule::new(i as u64).one_shot(site, kind, 1)).unwrap();
        let res = live.commit_batch(&gaussian(BATCH, D, 22));
        assert!(fires(site) >= 1, "{site}: the commit path never hit the failpoint");
        drop(guard);

        let err_text = res.as_ref().err().map(|e| e.to_string()).unwrap_or_default();
        assert_eq!(
            res.is_ok(),
            absorbed,
            "{site}/{kind:?}: expected {} ({err_text})",
            if absorbed { "retry absorption" } else { "a typed give-up" }
        );
        if let (FaultKind::Corrupt, Err(e)) = (kind, &res) {
            assert!(e.is_corrupt(), "{site}: injected corruption lost its kind: {e}");
        }

        // Graceful degradation: the store is still usable after the fault.
        live.commit_batch(&gaussian(BATCH, D, 33)).unwrap();
        let want_version = DatasetView::version(&live);
        assert_eq!(want_version, if absorbed { 3 } else { 2 }, "{site}: version accounting");
        let want_fp = fingerprint_view(&*live.pin());
        let want_rows = live.n_rows();
        drop(live);

        for pass in 0..2 {
            let (store, report) = LiveStore::recover(&dir, small_opts()).unwrap();
            assert_eq!(report.version, want_version, "{site} pass {pass}: version");
            assert_eq!(report.rows as usize, want_rows, "{site} pass {pass}: rows");
            assert!(report.dropped.is_none(), "{site} pass {pass}: nothing may be dropped");
            assert_eq!(report.truncated_bytes, 0, "{site} pass {pass}: manifest must be clean");
            assert_eq!(
                fingerprint_view(&*store.pin()),
                want_fp,
                "{site} pass {pass}: recovered bits"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

// ---------------------------------------------------------------------
// Exhausted retries are a typed give-up, not a panic and not a wedged
// store: a persistent manifest-append failure errors with
// ErrorKind::Exhausted, publishes nothing, leaves no orphan segment
// file, and the very next commit (fault cleared) succeeds.
// ---------------------------------------------------------------------
#[test]
fn exhausted_retries_give_up_typed_and_leave_the_store_usable() {
    let _g = chaos_lock();
    let dir = scratch_dir("exhausted");
    let live = LiveStore::open(D, small_opts(), &dir).unwrap();
    live.commit_batch(&gaussian(BATCH, D, 11)).unwrap();

    let guard = ScheduleGuard::install(
        Schedule::new(3).every("persist.manifest.append", FaultKind::Error, 1),
    )
    .unwrap();
    let err = live
        .commit_batch(&gaussian(BATCH, D, 22))
        .err()
        .expect("a persistent append failure must fail the commit");
    assert!(err.is_exhausted(), "persistent append failure must exhaust: {err}");
    drop(guard);

    assert_eq!(DatasetView::version(&live), 1, "failed commit must not publish");
    live.commit_batch(&gaussian(BATCH, D, 33)).unwrap();
    let want_fp = fingerprint_view(&*live.pin());
    drop(live);

    let (store, report) = LiveStore::recover(&dir, small_opts()).unwrap();
    assert_eq!(report.version, 2);
    assert!(report.dropped.is_none(), "the abandoned segment file must have been removed");
    assert_eq!(fingerprint_view(&*store.pin()), want_fp);
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// One-shot sweep, mutation path: delete and compact fail typed with
// nothing published; a transient manifest-rewrite failure inside
// compact is absorbed by the bounded retry. Recovery is bit-exact.
// ---------------------------------------------------------------------
#[test]
fn mutation_one_shot_faults_recover_bit_exact() {
    let _g = chaos_lock();
    let dir = scratch_dir("mutation_sweep");
    let live = LiveStore::open(D, small_opts(), &dir).unwrap();
    live.commit_batch(&gaussian(BATCH, D, 11)).unwrap();

    let guard =
        ScheduleGuard::install(Schedule::new(1).one_shot("live.delete", FaultKind::Error, 1))
            .unwrap();
    assert!(live.delete_rows(&[1, 2]).is_err());
    assert_eq!(fires("live.delete"), 1);
    drop(guard);
    assert_eq!(DatasetView::version(&live), 1, "failed delete must not publish");
    live.delete_rows(&[1, 2]).unwrap();
    live.commit_batch(&gaussian(BATCH, D, 22)).unwrap();

    let guard =
        ScheduleGuard::install(Schedule::new(2).one_shot("live.compact", FaultKind::Error, 1))
            .unwrap();
    assert!(live.compact().is_err());
    assert_eq!(fires("live.compact"), 1);
    drop(guard);
    assert_eq!(DatasetView::version(&live), 3, "failed compact must not publish");

    let guard = ScheduleGuard::install(
        Schedule::new(3).one_shot("persist.manifest.rewrite", FaultKind::Error, 1),
    )
    .unwrap();
    live.compact().unwrap();
    assert_eq!(fires("persist.manifest.rewrite"), 1, "compact never hit the rewrite failpoint");
    drop(guard);

    let want_version = DatasetView::version(&live);
    let want_fp = fingerprint_view(&*live.pin());
    drop(live);
    for pass in 0..2 {
        let (store, report) = LiveStore::recover(&dir, small_opts()).unwrap();
        assert_eq!(report.version, want_version, "pass {pass}");
        assert!(report.dropped.is_none(), "pass {pass}");
        assert_eq!(fingerprint_view(&*store.pin()), want_fp, "pass {pass}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// One-shot sweep, spill path: injected write/finish failures surface as
// typed build errors (no panic); an injected corrupt read quarantines
// the chunk — unhealthy store, fail-fast on re-touch with no extra disk
// read, other chunks still served.
// ---------------------------------------------------------------------
#[test]
fn spill_one_shot_faults_error_typed_and_quarantine() {
    let _g = chaos_lock();
    let opts = StoreOptions { rows_per_chunk: 64, ..Default::default() }.spill_to_temp(1024);
    let m = gaussian(256, 8, 5);

    for (seed, site) in [(1u64, "spill.write"), (2, "spill.finish")] {
        let guard =
            ScheduleGuard::install(Schedule::new(seed).one_shot(site, FaultKind::Error, 1))
                .unwrap();
        let res = ColumnStore::from_matrix(&m, &opts);
        assert!(fires(site) >= 1, "{site}: the spilling build never hit the failpoint");
        assert!(res.is_err(), "{site}: an injected spill fault must fail the build typed");
        drop(guard);
    }

    // Build clean, then poison the first spilled read.
    let cs = ColumnStore::from_matrix(&m, &opts).unwrap();
    assert!(cs.spilled(), "fixture must actually spill");
    let guard =
        ScheduleGuard::install(Schedule::new(3).one_shot("spill.read", FaultKind::Corrupt, 1))
            .unwrap();
    let hit = catch_unwind(AssertUnwindSafe(|| cs.get(0, 0)));
    assert!(hit.is_err(), "a corrupt spilled read must not return fabricated data");
    assert_eq!(fires("spill.read"), 1);
    drop(guard);

    assert!(!cs.healthy(), "quarantine must mark the store degraded");
    assert_eq!(cs.quarantined_chunks(), 1);
    let reads_after_fault = cs.spill_reads();
    let again = catch_unwind(AssertUnwindSafe(|| cs.get(0, 0)));
    assert!(again.is_err(), "a quarantined chunk must fail fast on re-touch");
    assert_eq!(cs.spill_reads(), reads_after_fault, "fail-fast must not re-read the disk");
    // A different block is untouched by the quarantine.
    let v = cs.get(64, 0);
    assert!(v.is_finite());
}

// ---------------------------------------------------------------------
// One-shot sweep, ingest handle: an injected submit fault is a typed
// error returned to the caller; the ingest thread survives and the next
// submit commits durably.
// ---------------------------------------------------------------------
#[test]
fn ingest_one_shot_fault_errors_typed_and_the_pipeline_survives() {
    let _g = chaos_lock();
    let dir = scratch_dir("ingest_sweep");
    let live = Arc::new(LiveStore::open(D, small_opts(), &dir).unwrap());
    let handle = live.spawn_ingest(2).unwrap();

    let guard =
        ScheduleGuard::install(Schedule::new(4).one_shot("live.ingest", FaultKind::Error, 1))
            .unwrap();
    assert!(handle.submit(gaussian(BATCH, D, 11)).is_err(), "injected submit fault must error");
    assert_eq!(fires("live.ingest"), 1);
    drop(guard);

    handle.submit(gaussian(BATCH, D, 22)).unwrap();
    handle.close();
    assert_eq!(DatasetView::version(&*live), 1, "exactly the clean submit must have committed");
    let want_fp = fingerprint_view(&*live.pin());
    drop(live);
    let (store, report) = LiveStore::recover(&dir, small_opts()).unwrap();
    assert_eq!(report.version, 1);
    assert_eq!(fingerprint_view(&*store.pin()), want_fp);
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// One-shot sweep, serve path: an injected panic inside query answering
// degrades exactly that query (typed `error` field, empty answer) —
// the batch, the server, and every other response survive, and the
// surviving responses stay bit-exact replayable after recovery.
// ---------------------------------------------------------------------
#[test]
fn serve_one_shot_panic_degrades_one_query_and_the_rest_replay() {
    let _g = chaos_lock();
    const DS: usize = 16;
    let dir = scratch_dir("serve_sweep");
    let opts = StoreOptions { rows_per_chunk: 16, ..Default::default() };
    let live = Arc::new(LiveStore::open(DS, opts.clone(), &dir).unwrap());
    live.commit_batch(&gaussian(64, DS, 5)).unwrap();

    let cfg = ServerConfig {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 200,
        validate_every: 0,
        ..Default::default()
    };
    let server = MipsServer::start(live.clone(), cfg.clone(), Backend::NativeBandit);
    let guard =
        ScheduleGuard::install(Schedule::new(5).one_shot("serve.query", FaultKind::Panic, 1))
            .unwrap();
    let mut rng = Rng::new(0xE0);
    let mut responses = Vec::new();
    for _ in 0..6 {
        let q: Vec<f32> = (0..DS).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let rx = server.submit(q.clone());
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("degraded, not dropped");
        responses.push((q, resp));
    }
    assert_eq!(fires("serve.query"), 1);
    drop(guard);
    server.shutdown();
    drop(live); // the crash

    let degraded: Vec<_> = responses.iter().filter(|(_, r)| r.error.is_some()).collect();
    assert_eq!(degraded.len(), 1, "exactly the injected query must degrade");
    assert!(degraded[0].1.top_atoms.is_empty(), "a degraded response carries no answer");
    for (q, resp) in responses.iter().filter(|(_, r)| r.error.is_none()) {
        assert!(!resp.top_atoms.is_empty());
        let snap = LiveStore::recover_snapshot(&dir, &opts, resp.version).unwrap();
        let mcfg = BanditMipsConfig {
            delta: cfg.delta,
            batch_size: 64,
            strategy: SampleStrategy::Uniform,
            sigma: None,
            k: cfg.k,
            seed: resp.seed,
            threads: 1,
        };
        let c = OpCounter::new();
        let again = bandit_mips_warm(&*snap, q, &mcfg, &c, &resp.warm_coords);
        assert_eq!(
            (&again.atoms, again.samples),
            (&resp.top_atoms, resp.samples),
            "survivor at v{} did not replay bit-exact",
            resp.version
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// One-shot sweep, executor: an injected task panic is contained by the
// worker (the pool survives and runs the next task); an injected gate
// stall delays admission but corrupts nothing.
// ---------------------------------------------------------------------
#[test]
fn exec_one_shot_faults_are_contained() {
    let _g = chaos_lock();
    let pool = WorkerPool::new(1);
    let guard =
        ScheduleGuard::install(Schedule::new(6).one_shot("exec.task", FaultKind::Panic, 1))
            .unwrap();
    let (tx, rx) = mpsc::channel();
    let tx1 = tx.clone();
    pool.spawn(move || {
        let _ = tx1.send(1u32); // killed by the injected panic before it runs
    });
    let tx2 = tx.clone();
    pool.spawn(move || {
        let _ = tx2.send(2u32);
    });
    drop(tx);
    let got = rx.recv_timeout(Duration::from_secs(30)).expect("worker died with the panic");
    assert_eq!(got, 2, "the injected panic must kill only the injected task");
    assert!(rx.recv_timeout(Duration::from_millis(200)).is_err(), "task 1 must never run");
    assert_eq!(fires("exec.task"), 1);
    drop(guard);

    let guard = ScheduleGuard::install(
        Schedule::new(7).one_shot("exec.gate.stall", FaultKind::Stall(150), 1),
    )
    .unwrap();
    let gate = Gate::new(1);
    let t0 = Instant::now();
    gate.acquire();
    assert!(t0.elapsed() >= Duration::from_millis(100), "the injected stall must delay admission");
    gate.release();
    assert_eq!(fires("exec.gate.stall"), 1);
    drop(guard);
}

// ---------------------------------------------------------------------
// One-shot sweep, scatter-gather: an injected shard-RPC fault (typed
// error or a panic inside the leg) loses exactly that shard — the
// answer comes back flagged `degraded` with `shards_ok == shards - 1`,
// no panic escapes, and with the fault cleared the same inputs produce
// the clean (non-degraded) answer again.
// ---------------------------------------------------------------------
#[test]
fn net_shard_rpc_one_shot_fault_degrades_to_partial_results() {
    let _g = chaos_lock();
    use adaptive_sampling::net::{ShardSet, SolveConfig};

    let view: Arc<dyn DatasetView> = Arc::new(gaussian(32, 8, 9));
    let set = ShardSet::new(view, 4);
    let q: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
    let scfg = SolveConfig { k: 2, delta: 1e-3, batch_size: 64 };

    for (seed, kind) in [(8u64, FaultKind::Error), (9, FaultKind::Panic)] {
        let guard =
            ScheduleGuard::install(Schedule::new(seed).one_shot("net.shard.rpc", kind, 1))
                .unwrap();
        let hit = set.solve(&q, 0xF00D, &[], &scfg, &OpCounter::new());
        assert_eq!(fires("net.shard.rpc"), 1, "{kind:?}: the scatter never hit the failpoint");
        drop(guard);
        assert!(hit.degraded, "{kind:?}: a lost shard must flag the answer");
        assert_eq!(hit.shards, 4);
        assert_eq!(hit.shards_ok, 3, "{kind:?}: exactly one leg may be lost");
    }

    let clean = set.solve(&q, 0xF00D, &[], &scfg, &OpCounter::new());
    assert!(!clean.degraded, "with chaos cleared the answer must be whole again");
    assert_eq!(clean.shards_ok, 4);
    assert_eq!(clean.top_atoms.len(), 2);
}

// ---------------------------------------------------------------------
// One-shot sweep, accept path: an injected accept fault drops exactly
// that connection (the client sees a reset, never a hang); the accept
// loop survives and the very next connection is served normally.
// ---------------------------------------------------------------------
#[test]
fn net_accept_one_shot_fault_drops_one_connection_and_the_listener_survives() {
    let _g = chaos_lock();
    use adaptive_sampling::net::{NetClient, NetConfig, NetServer, ServeTarget};

    let view: Arc<dyn DatasetView> = Arc::new(gaussian(32, 8, 7));
    let cfg = NetConfig { shards: 2, read_timeout_ms: 5_000, ..Default::default() };
    let server = NetServer::start(ServeTarget::Static(view), "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();

    let guard =
        ScheduleGuard::install(Schedule::new(10).one_shot("net.accept", FaultKind::Error, 1))
            .unwrap();
    // The kernel completes the handshake, so connect succeeds; the
    // injected fault then drops the stream before any frame is read.
    let denied = NetClient::connect(&addr, 5_000).and_then(|mut c| c.hello("denied"));
    assert!(denied.is_err(), "the faulted accept must reset the connection, got {denied:?}");
    assert_eq!(fires("net.accept"), 1, "the accept loop never hit the failpoint");
    drop(guard);

    let welcome = NetClient::connect(&addr, 5_000)
        .and_then(|mut c| c.hello("ok"))
        .expect("the listener must survive the injected accept fault");
    assert_eq!((welcome.rows, welcome.d, welcome.shards), (32, 8, 2));
    server.shutdown();
}

// ---------------------------------------------------------------------
// The random walk: ingest/serve under a probabilistic fault schedule,
// crash (plus deterministic manifest scribbling), recover twice, replay
// every served triple. `WalkReport::ok()` is the tentpole invariant —
// no panic escaped, recovery was idempotent, no torn version was
// served, every survivor replayed bit-exact.
// ---------------------------------------------------------------------
#[test]
fn random_walk_under_default_schedule_holds_every_invariant() {
    let _g = chaos_lock();
    let dir = scratch_dir("walk");
    let cfg = driver::WalkConfig::smoke(dir.clone(), 0xA11CE);
    let report = driver::run_walk(&cfg).unwrap();
    assert!(
        report.ok(),
        "chaos walk violations (seed {:#x}):\n{}",
        cfg.seed,
        report.violations.join("\n")
    );
    assert_eq!(report.cycles as usize, cfg.cycles);
    assert_eq!(report.recoveries, 2 * cfg.cycles as u64, "two recovery passes per cycle");
    assert!(report.commits_ok + report.commits_failed > 0, "the walk must attempt commits");
    assert!(
        report.queries_ok + report.queries_degraded + report.queries_lost > 0,
        "the walk must serve queries"
    );
    assert_eq!(report.replayed, report.queries_ok, "every surviving triple must be replayed");
    let _ = std::fs::remove_dir_all(&dir);
}
