//! Kernel-layer acceptance tests: the batched `DatasetView` hooks and
//! the block-scheduled solver pulls must be *bit-identical* to the
//! scalar path (values AND op-counter totals), the fused quantized reads
//! must match decode-then-read exactly, and the quantized serving path
//! must run allocation- and decode-free in steady state.
//!
//! The scalar reference is `testkit::ScalarView`, which hides a view's
//! batched overrides so every hook falls back to its trait default —
//! exactly the pre-kernel per-pull path.

mod common;

use std::sync::Arc;

use adaptive_sampling::data::distance::Metric;
use adaptive_sampling::data::VecPointSet;
use adaptive_sampling::forest::histogram::Impurity;
use adaptive_sampling::forest::split::{
    feature_ranges_view, make_edges, solve_mab_threaded, SplitContext, TrainSet,
};
use adaptive_sampling::kernels::scratch;
use adaptive_sampling::kmedoids::banditpam::{bandit_pam, BanditPamConfig};
use adaptive_sampling::metrics::OpCounter;
use adaptive_sampling::mips::banditmips::{bandit_mips, BanditMipsConfig};
use adaptive_sampling::store::{ColumnStore, DatasetView, LiveStore, RowSubsetView, StoreOptions};
use adaptive_sampling::store::{Codec, ViewPointSet};
use adaptive_sampling::util::proptest::prop_check;
use adaptive_sampling::util::rng::Rng;
use adaptive_sampling::util::testkit::{self, ScalarView};

/// Compare every batched hook against the ScalarView defaults, bit for
/// bit, over the given view.
fn assert_batched_hooks_match_scalar(
    v: &dyn DatasetView,
    rows: &[usize],
    cols: &[usize],
    seed: u64,
) {
    let scalar = ScalarView(v);
    let d = v.n_cols();
    let mut rng = Rng::new(seed);
    let q: Vec<f32> = (0..d).map(|_| rng.f32() * 4.0 - 2.0).collect();

    // gather_block
    let mut got = vec![f32::NAN; rows.len() * cols.len()];
    let mut want = vec![f32::NAN; rows.len() * cols.len()];
    v.gather_block(rows, cols, &mut got);
    scalar.gather_block(rows, cols, &mut want);
    for (k, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "gather_block[{k}]: {g} vs {w}");
    }

    // gather_rows
    let mut got = vec![f32::NAN; rows.len() * d];
    let mut want = vec![f32::NAN; rows.len() * d];
    v.gather_rows(rows, &mut got);
    scalar.gather_rows(rows, &mut want);
    for (k, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "gather_rows[{k}]: {g} vs {w}");
    }

    // dot_batch
    let mut got = vec![f64::NAN; rows.len()];
    let mut want = vec![f64::NAN; rows.len()];
    v.dot_batch(rows, &q, &mut got);
    scalar.dot_batch(rows, &q, &mut want);
    for (k, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "dot_batch[{k}]: {g} vs {w}");
    }

    // dist_point_batch, all three metrics
    let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        let mut got = vec![f64::NAN; rows.len()];
        let mut want = vec![f64::NAN; rows.len()];
        v.dist_point_batch(metric, &x, rows, &mut got);
        scalar.dist_point_batch(metric, &x, rows, &mut want);
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "dist_point_batch/{metric}[{k}]");
        }
    }

    // for_each_col_block: concatenated runs must equal read_col exactly,
    // with run starts tiling [0, rows.len()) in order.
    for &c in cols.iter().take(2) {
        let mut want = vec![f32::NAN; rows.len()];
        v.read_col(c, rows, &mut want);
        let mut got = vec![f32::NAN; rows.len()];
        let mut next = 0usize;
        v.for_each_col_block(c, rows, &mut |start, vals| {
            assert_eq!(start, next, "runs must tile in order");
            got[start..start + vals.len()].copy_from_slice(vals);
            next = start + vals.len();
        });
        assert_eq!(next, rows.len(), "runs must cover every row");
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "for_each_col_block col {c} [{k}]");
        }
    }
}

#[test]
fn prop_batched_hooks_bit_identical_to_scalar_for_random_shapes() {
    // Satellite acceptance: random shapes and strides, tails (n % 8 ≠ 0,
    // d % 8 ≠ 0, n % rows_per_chunk ≠ 0), scattered and duplicated row
    // subsets, RAM and spilled backings — every batched hook must equal
    // the scalar default bit for bit on F32 data.
    prop_check(
        0xBA7C4,
        18,
        |r| {
            let n = 1 + r.below(300);
            let d = 1 + r.below(40);
            let rpc = 16 * (1 + r.below(4));
            let spill = r.below(3) == 0;
            (n, d, rpc, spill, r.next_u64())
        },
        |&(n, d, rpc, spill, seed)| {
            let m = testkit::gaussian(n, d, seed);
            let mut opts = StoreOptions { rows_per_chunk: rpc, ..Default::default() };
            if spill {
                opts = opts.spill_to_temp(1024); // tiny budget: force evictions
            }
            let cs = ColumnStore::from_matrix(&m, &opts).map_err(|e| e.to_string())?;
            let mut rng = Rng::new(seed ^ 0x515E);
            // Ascending subset with duplicates, plus a scattered subset.
            let mut asc: Vec<usize> = (0..1 + rng.below(n.min(64))).map(|_| rng.below(n)).collect();
            asc.sort_unstable();
            let scattered: Vec<usize> =
                (0..1 + rng.below(n.min(64))).map(|_| rng.below(n)).collect();
            let cols: Vec<usize> = (0..1 + rng.below(d)).map(|_| rng.below(d)).collect();
            for rows in [&asc, &scattered] {
                assert_batched_hooks_match_scalar(&cs, rows, &cols, seed);
                assert_batched_hooks_match_scalar(&m, rows, &cols, seed);
            }
            // Empty batches are no-ops, not panics.
            let no_rows: [usize; 0] = [];
            let no_q: [f32; 0] = [];
            cs.gather_block(&no_rows, &cols, &mut []);
            cs.gather_block(&asc, &no_rows, &mut []);
            cs.dot_batch(&no_rows, &no_q, &mut []);
            Ok(())
        },
    );
}

#[test]
fn batched_hooks_cover_edge_shapes() {
    // Single-row store, single-row blocks, and batches touching one row.
    for (n, d, rpc) in [(1usize, 3usize, 16usize), (5, 9, 16), (17, 1, 16)] {
        let m = testkit::gaussian(n, d, 9);
        let cs = ColumnStore::from_matrix(
            &m,
            &StoreOptions { rows_per_chunk: rpc, ..Default::default() },
        )
        .unwrap();
        let rows: Vec<usize> = (0..n).collect();
        let cols: Vec<usize> = (0..d).collect();
        assert_batched_hooks_match_scalar(&cs, &rows, &cols, 5);
        assert_batched_hooks_match_scalar(&cs, &[0], &cols, 6);
    }
}

#[test]
fn fused_quantized_reads_match_decode_path_bitwise_and_error_bound() {
    // Satellite acceptance: the fused I8/F16 kernels read encoded bytes
    // in place; their values must equal the decode-then-read path bit
    // for bit (same arithmetic), and stay within the codec's published
    // error_bound of the original values.
    let m = testkit::gaussian(300, 9, 31);
    for codec in [Codec::I8, Codec::F16] {
        // int_domain pinned off: this test is the bitwise contract of the
        // decode-to-f32 chain (the integer-domain path is exercised — and
        // envelope-bounded — separately below).
        let opts =
            StoreOptions { codec, rows_per_chunk: 64, int_domain: false, ..Default::default() };
        let cs = ColumnStore::from_matrix(&m, &opts).unwrap();
        let rows: Vec<usize> = (0..m.n).step_by(3).collect();
        let cols: Vec<usize> = (0..m.d).collect();
        // Bitwise vs the scalar (decode-through-cache) path.
        assert_batched_hooks_match_scalar(&cs, &rows, &cols, 77);
        // Error bound vs the original matrix, chunk by chunk.
        let mut got = vec![0f32; rows.len() * cols.len()];
        cs.gather_block(&rows, &cols, &mut got);
        for (ri, &r) in rows.iter().enumerate() {
            for (ci, &c) in cols.iter().enumerate() {
                let s = cs.chunk_stats(c, r / cs.chunk_rows());
                let bound = codec.error_bound(s.min, s.max) * (1.0 + 1e-4) + 1e-12;
                let err = (m.row(r)[c] as f64 - got[ri * cols.len() + ci] as f64).abs();
                assert!(err <= bound, "{codec:?} ({r},{c}): err {err} > bound {bound}");
            }
        }
        // Fused dot: identical to decode-then-dot (the scalar hook).
        let q: Vec<f32> = (0..m.d).map(|i| i as f32 * 0.5 - 2.0).collect();
        let mut fused = vec![0f64; rows.len()];
        cs.dot_batch(&rows, &q, &mut fused);
        for (k, &r) in rows.iter().enumerate() {
            assert_eq!(fused[k].to_bits(), ScalarView(&cs).dot(r, &q).to_bits());
        }
    }
}

#[test]
fn live_snapshot_and_row_subset_hooks_match_scalar() {
    // Multi-segment snapshot with tombstones: the run-grouped batched
    // hooks must still be bit-identical to the scalar defaults.
    let a = testkit::gaussian(70, 6, 41);
    let b = testkit::gaussian(40, 6, 42);
    let live =
        LiveStore::new(6, StoreOptions { rows_per_chunk: 16, ..Default::default() }).unwrap();
    live.commit_batch(&a).unwrap();
    live.commit_batch(&b).unwrap();
    let snap = live.delete_rows(&[0, 35, 80]).unwrap();
    let n = snap.n_rows();
    let mut rng = Rng::new(7);
    let rows: Vec<usize> = (0..48).map(|_| rng.below(n)).collect();
    let cols = vec![0usize, 5, 2, 2];
    assert_batched_hooks_match_scalar(&*snap, &rows, &cols, 43);

    // RowSubsetView translation preserves bit-identity too.
    let base = testkit::gaussian(90, 7, 44);
    let subset: Vec<usize> = (0..30).map(|_| rng.below(90)).collect();
    let sub = RowSubsetView::new(&base, subset);
    let sub_rows: Vec<usize> = (0..20).map(|_| rng.below(30)).collect();
    let sub_cols = vec![6usize, 0, 3];
    assert_batched_hooks_match_scalar(&sub, &sub_rows, &sub_cols, 45);
}

/// Run BanditMIPS and return everything the determinism contract pins.
fn run_mips(v: &dyn DatasetView, q: &[f32], threads: usize) -> (Vec<usize>, u64, u64) {
    let c = OpCounter::new();
    let cfg = BanditMipsConfig { k: 2, threads, seed: 99, ..Default::default() };
    let ans = bandit_mips(v, q, &cfg, &c);
    (ans.atoms, ans.samples, c.get())
}

#[test]
fn banditmips_batched_pulls_bit_identical_to_scalar_at_every_thread_count() {
    // Tentpole acceptance: for a fixed seed, the block-scheduled solver
    // returns bit-identical answers AND op-counter totals to the scalar
    // path on Matrix and ColumnStore(F32) at threads {1, 2, 4, 8} — the
    // satellite's "one batched call over B rows counts as B pulls".
    let m = testkit::gaussian(120, 96, 51);
    let cs = ColumnStore::from_matrix(
        &m,
        &StoreOptions { rows_per_chunk: 32, ..Default::default() },
    )
    .unwrap();
    let mut rng = Rng::new(5);
    let q: Vec<f32> = (0..96).map(|_| rng.f32() * 3.0 - 1.5).collect();
    let reference = run_mips(&ScalarView(&m), &q, 1);
    for threads in [1usize, 2, 4, 8] {
        assert_eq!(run_mips(&m, &q, threads), reference, "matrix threads={threads}");
        assert_eq!(run_mips(&cs, &q, threads), reference, "store threads={threads}");
        assert_eq!(
            run_mips(&ScalarView(&cs), &q, threads),
            reference,
            "scalar store threads={threads}"
        );
    }
}

#[test]
fn banditpam_batched_distance_pulls_bit_identical_to_scalar() {
    let m = testkit::clusterable(130, 12, 3, 6.0, 53).x;
    let cs = Arc::new(
        ColumnStore::from_matrix(&m, &StoreOptions { rows_per_chunk: 32, ..Default::default() })
            .unwrap(),
    );
    let run = |scalar: bool, threads: usize| {
        let mut cfg = BanditPamConfig::new(3);
        cfg.km.seed = 53;
        cfg.threads = threads;
        let r = if scalar {
            let sv = ScalarView(&*cs);
            bandit_pam(&ViewPointSet::new(Arc::new(sv), Metric::L2), &cfg)
        } else {
            bandit_pam(&ViewPointSet::new(cs.clone(), Metric::L2), &cfg)
        };
        (r.medoids, r.loss.to_bits(), r.swaps_performed, r.dist_calls)
    };
    let dense = {
        let mut cfg = BanditPamConfig::new(3);
        cfg.km.seed = 53;
        let r = bandit_pam(&VecPointSet::new(m.clone(), Metric::L2), &cfg);
        (r.medoids, r.loss.to_bits(), r.swaps_performed, r.dist_calls)
    };
    for threads in [1usize, 2, 4, 8] {
        assert_eq!(run(false, threads), dense, "batched threads={threads}");
        assert_eq!(run(true, threads), dense, "scalar threads={threads}");
    }
}

#[test]
fn mabsplit_batched_fills_bit_identical_to_scalar() {
    let ds = adaptive_sampling::data::tabular::make_classification(2_500, 8, 3, 2, 2.5, 57);
    let cs = ColumnStore::from_matrix(
        &ds.x,
        &StoreOptions { rows_per_chunk: 128, ..Default::default() },
    )
    .unwrap();
    let rows: Vec<usize> = (0..ds.x.n).collect();
    let features: Vec<usize> = (0..ds.x.d).collect();
    let run = |x: &dyn DatasetView, threads: usize| {
        let c = OpCounter::new();
        let ranges = feature_ranges_view(x);
        let mut rng = Rng::new(1);
        let ctx = SplitContext {
            ds: TrainSet { x, y: &ds.y, n_classes: ds.n_classes },
            rows: &rows,
            features: &features,
            edges: make_edges(&features, &ranges, 10, false, &mut rng),
            impurity: Impurity::Gini,
            counter: &c,
        };
        let s = solve_mab_threaded(&ctx, 100, 0.01, 57, threads).unwrap();
        (s.feature, s.threshold.to_bits(), s.child_impurity.to_bits(), c.get())
    };
    let reference = run(&ScalarView(&ds.x), 1);
    for threads in [1usize, 2, 4, 8] {
        assert_eq!(run(&ds.x, threads), reference, "matrix threads={threads}");
        assert_eq!(run(&cs, threads), reference, "store threads={threads}");
        assert_eq!(run(&ScalarView(&cs), threads), reference, "scalar store threads={threads}");
    }
}

#[test]
fn quantized_serving_path_is_allocation_and_decode_free_in_steady_state() {
    // Tentpole acceptance: on an in-RAM I8 store, a serving query
    // performs ZERO full-chunk Vec<f32> decodes (the fused path reads
    // encoded bytes in place), and after one warm-up query the scratch
    // arenas stop growing — zero per-pull heap allocations.
    let m = testkit::gaussian(256, 64, 61);
    let cs = ColumnStore::from_matrix(
        &m,
        &StoreOptions { codec: Codec::I8, rows_per_chunk: 64, ..Default::default() },
    )
    .unwrap();
    assert!(!cs.spilled());
    let mut rng = Rng::new(8);
    let q: Vec<f32> = (0..64).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let cfg = BanditMipsConfig { k: 3, threads: 1, ..Default::default() };

    // Warm-up: arenas grow to the steady-state shapes.
    let c = OpCounter::new();
    let warm = bandit_mips(&cs, &q, &cfg, &c);
    assert_eq!(cs.chunk_decodes(), 0, "fused path must not materialize chunks");
    let grows_after_warmup = scratch::grow_events();

    // Steady state: same shapes, zero arena growth, still zero decodes.
    let c2 = OpCounter::new();
    let again = bandit_mips(&cs, &q, &cfg, &c2);
    assert_eq!(again.atoms, warm.atoms, "same query, same answer");
    assert_eq!(
        scratch::grow_events(),
        grows_after_warmup,
        "steady-state serving must not grow the scratch arenas"
    );
    assert_eq!(cs.chunk_decodes(), 0, "still decode-free after the second query");
    // The decode-op meter still charges the touched elements, so lossy
    // access cost stays visible.
    assert!(cs.decode_ops() > 0);
    // The LRU cache was never consulted on the fused path.
    let cache = cs.cache_counters();
    assert_eq!((cache.hits, cache.misses), (0, 0), "fused path bypasses the cache");
}

#[test]
fn prop_integer_domain_dot_within_envelope_and_thread_invariant() {
    // Satellite acceptance: the i32-domain dot stays within the
    // documented envelope of the decode-to-f32 chain — per chunk run,
    // (W/2)·Σ u_c with W the weight-grid step, bounded here via each
    // block's own stats (u ≤ 255 per element) — and the integer path
    // keeps the determinism contract: bit-identical answers, samples,
    // and op totals at threads {1, 8}.
    prop_check(
        0x1D07,
        12,
        |r| (16 + r.below(200), 1 + r.below(24), r.next_u64()),
        |&(n, d, seed)| {
            let m = testkit::gaussian(n, d, seed);
            let mk = |int_domain: bool| {
                ColumnStore::from_matrix(
                    &m,
                    &StoreOptions {
                        codec: Codec::I8,
                        rows_per_chunk: 32,
                        int_domain,
                        ..Default::default()
                    },
                )
                .map_err(|e| e.to_string())
            };
            let f32dom = mk(false)?;
            let intdom = mk(true)?;
            let mut rng = Rng::new(seed ^ 0x17);
            let q: Vec<f32> = (0..d).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let rows: Vec<usize> = (0..n).collect();
            let (mut a, mut b) = (vec![0f64; n], vec![0f64; n]);
            f32dom.dot_batch(&rows, &q, &mut a);
            intdom.dot_batch(&rows, &q, &mut b);
            for (r, (x, y)) in a.iter().zip(&b).enumerate() {
                let blk = r / intdom.chunk_rows();
                let mut w_max = 0f64;
                for c in 0..d {
                    let s = intdom.chunk_stats(c, blk);
                    let scale = ((s.max as f64) - (s.min as f64)) / 255.0;
                    w_max = w_max.max((q[c] as f64 * scale).abs());
                }
                let bound = 0.5 * (w_max / 127.0) * 255.0 * d as f64 + 1e-3;
                if (x - y).abs() > bound {
                    return Err(format!("row {r}: f32dom {x} vs intdom {y} (bound {bound})"));
                }
            }
            let seq = run_mips(&intdom, &q, 1);
            if run_mips(&intdom, &q, 8) != seq {
                return Err("int-domain MIPS diverged at threads=8".into());
            }
            Ok(())
        },
    );
}

#[test]
fn spilled_quantized_store_still_serves_through_the_cache() {
    // Spilled chunks amortize disk reads through the LRU decoded cache;
    // the batched hooks pin a chunk once per run instead of per element,
    // and the hit/miss counters make that visible.
    let m = testkit::gaussian(512, 24, 67);
    let opts = StoreOptions { codec: Codec::I8, rows_per_chunk: 64, ..Default::default() }
        .spill_to_temp(1 << 20);
    let cs = ColumnStore::from_matrix(&m, &opts).unwrap();
    assert!(cs.spilled());
    let mut rng = Rng::new(9);
    let q: Vec<f32> = (0..24).map(|_| rng.f32()).collect();
    let c = OpCounter::new();
    let _ = bandit_mips(&cs, &q, &BanditMipsConfig::default(), &c);
    let cache = cs.cache_counters();
    assert!(cache.misses > 0, "spilled serving decodes through the cache");
    assert!(cache.hits > 0, "…and reuses decoded chunks across batches");
    assert!(cs.chunk_decodes() > 0);
}
