//! Crash-recovery tests for the durable `LiveStore`: torn manifest and
//! torn segment tails at every byte boundary, bit-exact replay of served
//! `(version, seed, warm_coords)` triples off the manifest alone, and a
//! real `kill -9` mid-ingest with recovery of every complete version.

mod common;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adaptive_sampling::coordinator::{Backend, MipsServer, ServerConfig};
use adaptive_sampling::data::Matrix;
use adaptive_sampling::metrics::OpCounter;
use adaptive_sampling::mips::banditmips::{bandit_mips_warm, BanditMipsConfig, SampleStrategy};
use adaptive_sampling::store::persist::{self, ManifestRecord};
use adaptive_sampling::store::{DatasetView, LiveStore, StoreOptions};
use adaptive_sampling::util::rng::Rng;
use common::*;

static DIR_SERIAL: AtomicU64 = AtomicU64::new(0);

/// A process-unique scratch data directory under the system temp dir.
fn scratch_dir(tag: &str) -> PathBuf {
    let serial = DIR_SERIAL.fetch_add(1, Ordering::Relaxed);
    let name = format!("as_durability_{tag}_{}_{serial}", std::process::id());
    std::env::temp_dir().join(name)
}

/// Flat copy (data dirs hold no subdirectories).
fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

const D: usize = 4;
const BATCH: usize = 16;

fn small_opts() -> StoreOptions {
    StoreOptions { rows_per_chunk: 8, ..Default::default() }
}

/// Build the shared crash fixture under `dir` — three commits with a
/// delete in between (versions 1..=4) — and return the bit-exact
/// fingerprint of every published version, indexed by version.
fn build_fixture(dir: &Path) -> Vec<u64> {
    let live = LiveStore::open(D, small_opts(), dir).unwrap();
    let mut fps = vec![fingerprint_view(&*live.pin())];
    fps.push(fingerprint_view(&*live.commit_batch(&gaussian(BATCH, D, 11)).unwrap()));
    fps.push(fingerprint_view(&*live.commit_batch(&gaussian(BATCH, D, 12)).unwrap()));
    fps.push(fingerprint_view(&*live.delete_rows(&[3, 17]).unwrap()));
    fps.push(fingerprint_view(&*live.commit_batch(&gaussian(BATCH, D, 13)).unwrap()));
    fps
}

/// Truncating the manifest at EVERY byte boundary recovers the longest
/// valid prefix bit-exact — never a panic, never a blended state. Cuts
/// inside the header line are the one case with nothing to recover; they
/// must fail with a typed error.
#[test]
fn torn_manifest_tails_recover_to_a_valid_prefix_at_every_byte() {
    let src = scratch_dir("manifest_src");
    let fps = build_fixture(&src);
    let header_len = ManifestRecord::Header { d: D as u64 }.to_line().len();
    let bytes = std::fs::read(src.join(persist::MANIFEST_NAME)).unwrap();
    assert!(bytes.len() > header_len, "fixture manifest holds more than the header");

    for cut in 0..=bytes.len() {
        let dir = scratch_dir("manifest_cut");
        copy_dir(&src, &dir);
        std::fs::write(dir.join(persist::MANIFEST_NAME), &bytes[..cut]).unwrap();
        match LiveStore::recover(&dir, small_opts()) {
            Err(e) => {
                assert!(cut < header_len, "cut {cut}: recovery failed past the header: {e}");
            }
            Ok((store, report)) => {
                assert!(cut >= header_len, "cut {cut}: header cannot be complete yet");
                let v = report.version as usize;
                assert!(v < fps.len(), "cut {cut}: impossible version {v}");
                let snap = store.pin();
                assert_eq!(DatasetView::version(&*snap), report.version, "cut {cut}");
                assert_eq!(snap.n_rows(), report.rows, "cut {cut}");
                assert_eq!(fingerprint_view(&*snap), fps[v], "cut {cut}: version {v} bits");
                // A torn tail is truncated on recovery, so the log ends
                // exactly where the replayed prefix does.
                let len = std::fs::metadata(dir.join(persist::MANIFEST_NAME)).unwrap().len();
                assert!(len <= cut as u64, "cut {cut}: log grew");
                // Spot-check that the recovered store stays writable.
                if cut % 64 == 0 || cut == bytes.len() {
                    let snap2 = store.commit_batch(&gaussian(4, D, 99)).unwrap();
                    assert_eq!(snap2.n_rows(), report.rows + 4, "cut {cut}: commit after");
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&src).unwrap();
}

/// Truncating the newest segment file at EVERY byte boundary drops the
/// commit that references it (checksums catch the tear), recovery lands
/// on the prior version bit-exact, and a second recovery is clean: the
/// first one truncated the manifest past the bad record and swept the
/// torn file.
#[test]
fn torn_segment_files_drop_their_commit_and_recover_clean() {
    let src = scratch_dir("segment_src");
    let fps = build_fixture(&src);
    // The newest segment (highest serial) backs the version-4 commit.
    let last = std::fs::read_dir(&src)
        .unwrap()
        .filter_map(|e| {
            let name = e.unwrap().file_name().to_string_lossy().into_owned();
            let stem = name.strip_prefix("seg-")?.strip_suffix(".seg")?;
            Some((stem.parse::<u64>().ok()?, name))
        })
        .max()
        .map(|(_, name)| name)
        .unwrap();
    let bytes = std::fs::read(src.join(&last)).unwrap();

    for cut in 0..bytes.len() {
        let dir = scratch_dir("segment_cut");
        copy_dir(&src, &dir);
        std::fs::write(dir.join(&last), &bytes[..cut]).unwrap();
        let (store, report) = LiveStore::recover(&dir, small_opts()).unwrap();
        assert_eq!(report.version, 3, "cut {cut}: last good version");
        assert!(report.dropped.is_some(), "cut {cut}: the tear must be reported");
        assert_eq!(fingerprint_view(&*store.pin()), fps[3], "cut {cut}: version 3 bits");
        assert!(!dir.join(&last).exists(), "cut {cut}: torn segment must be swept");
        drop(store);
        if cut % 16 == 0 || cut + 1 == bytes.len() {
            let (store2, r2) = LiveStore::recover(&dir, small_opts()).unwrap();
            assert_eq!(r2.version, 3, "cut {cut}: second recovery");
            assert!(r2.dropped.is_none(), "cut {cut}: second recovery must be clean");
            assert_eq!(r2.truncated_bytes, 0, "cut {cut}: nothing left to truncate");
            drop(store2);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&src).unwrap();
}

/// The serving acceptance contract: answer queries against a live
/// durable store while batches keep committing, drop every handle (the
/// simulated crash), then replay each response's `(version, seed,
/// warm_coords)` triple on a snapshot re-pinned from the manifest alone.
/// Every answer and sample count must reproduce bit-exact — served
/// segments are published from the same durable bytes recovery reads.
#[test]
fn served_triples_reproduce_bit_exact_from_the_recovered_manifest() {
    const DS: usize = 32;
    let dir = scratch_dir("triples");
    let opts = StoreOptions { rows_per_chunk: 16, ..Default::default() };
    let live = Arc::new(LiveStore::open(DS, opts.clone(), &dir).unwrap());
    live.commit_batch(&gaussian(64, DS, 5)).unwrap();

    let cfg = ServerConfig {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 200,
        validate_every: 0,
        ..Default::default()
    };
    let server = Arc::new(MipsServer::start(live.clone(), cfg.clone(), Backend::NativeBandit));
    let ingest = {
        let live = live.clone();
        std::thread::spawn(move || {
            for b in 0..6u64 {
                live.commit_batch(&gaussian(12, DS, 100 + b)).unwrap();
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };
    let mut rng = Rng::new(0xD0);
    let mut responses = Vec::new();
    for _ in 0..40 {
        let q: Vec<f32> = (0..DS).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let rx = server.submit(q.clone());
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        responses.push((q, resp));
    }
    ingest.join().unwrap();
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("server still referenced after joins"),
    }
    drop(live); // the crash: nothing survives but the data directory

    let mut versions_seen = std::collections::HashSet::new();
    for (q, resp) in &responses {
        versions_seen.insert(resp.version);
        let snap = LiveStore::recover_snapshot(&dir, &opts, resp.version).unwrap();
        let mcfg = BanditMipsConfig {
            delta: cfg.delta,
            batch_size: 64,
            strategy: SampleStrategy::Uniform,
            sigma: None,
            k: cfg.k,
            seed: resp.seed,
            threads: 1,
        };
        let c = OpCounter::new();
        let again = bandit_mips_warm(&*snap, q, &mcfg, &c, &resp.warm_coords);
        assert_eq!(
            (&again.atoms, again.samples),
            (&resp.top_atoms, resp.samples),
            "served answer at v{} did not survive recovery",
            resp.version
        );
    }
    assert!(!versions_seen.is_empty());
    std::fs::remove_dir_all(&dir).unwrap();
}

const CHILD_ENV: &str = "AS_DURABILITY_CHILD_DIR";
const CHILD_D: usize = 16;
const CHILD_BATCH: usize = 8;
const CHILD_SEED: u64 = 0xC0FFEE;

fn child_opts() -> StoreOptions {
    StoreOptions { rows_per_chunk: 8, ..Default::default() }
}

/// Not a test of its own: when spawned by the kill-9 test below (the env
/// var is set), this process ingests deterministic batches into the
/// shared data directory until it is killed. Without the env var it is
/// an immediate no-op, so a normal `cargo test` run is unaffected.
#[test]
fn child_ingest_helper() {
    let Ok(dir) = std::env::var(CHILD_ENV) else { return };
    let live = LiveStore::open(CHILD_D, child_opts(), Path::new(&dir)).unwrap();
    for b in 0..100_000u64 {
        live.commit_batch(&gaussian(CHILD_BATCH, CHILD_D, CHILD_SEED + b)).unwrap();
    }
}

/// The ISSUE acceptance test: `kill -9` a child process mid-ingest, then
/// recover its data directory and check that every complete committed
/// version survived — the recovered rows are bit-identical to the
/// deterministic batches the child was writing, in order, with nothing
/// blended in from the batch the kill interrupted.
#[test]
fn kill_nine_mid_ingest_recovers_every_complete_version_bit_exact() {
    let dir = scratch_dir("kill9");
    let exe = std::env::current_exe().unwrap();
    let mut child = std::process::Command::new(exe)
        .args(["child_ingest_helper", "--exact", "--nocapture"])
        .env(CHILD_ENV, &dir)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn child ingester");

    // Wait until the child has durably logged a few commits, then kill
    // it dead (SIGKILL — no destructors, no flushes).
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let commits = std::fs::read_to_string(dir.join(persist::MANIFEST_NAME))
            .map(|s| s.matches("\"op\":\"commit\"").count())
            .unwrap_or(0);
        if commits >= 3 {
            break;
        }
        if child.try_wait().expect("child status").is_some() {
            panic!("child ingester exited before it could be killed");
        }
        assert!(Instant::now() < deadline, "child never reached 3 commits");
        std::thread::sleep(Duration::from_millis(5));
    }
    child.kill().expect("kill");
    let _ = child.wait();

    let (store, report) = LiveStore::recover(&dir, child_opts()).unwrap();
    assert!(report.version >= 3, "at least the polled commits must survive: {report:?}");
    let mats: Vec<Matrix> = (0..report.version)
        .map(|b| gaussian(CHILD_BATCH, CHILD_D, CHILD_SEED + b))
        .collect();
    let refs: Vec<&Matrix> = mats.iter().collect();
    let expect = stack(&refs);
    let snap = store.pin();
    assert_eq!(snap.n_rows(), expect.n, "recovered rows == complete batches");
    assert_views_bit_identical(&*snap, &expect);
    drop(snap);
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}
