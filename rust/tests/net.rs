//! Acceptance tests for the TCP serving tier (`net/`): the three
//! tentpole contracts plus the frame-codec robustness discipline.
//!
//! - **Shard invariance**: scatter-gather top-k over {1, 2, 4, 8}
//!   shards is bit-identical to single-shard (and to brute force) in
//!   the exact regime — partitioning is an implementation detail, never
//!   an answer change.
//! - **Replay**: every wire answer's `(version, seed, warm_coords)`
//!   triple reproduces the exact `top_atoms` and `samples` offline from
//!   the durable directory alone, across mid-stream wire ingest.
//! - **Graceful degradation**: overload sheds with typed `overloaded`
//!   frames (admitted queries still replay bit-exact), a lost shard
//!   yields a flagged partial result, quotas deny per client, shutdown
//!   drains, and malformed bytes get typed `bad_frame` answers — never
//!   a panic, never a hang.
//!
//! Chaos state is process-global, so fault-injecting tests serialize on
//! [`net_chaos_lock`].

mod common;

use std::io::Write as _;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use adaptive_sampling::chaos::{FaultKind, Schedule, ScheduleGuard};
use adaptive_sampling::metrics::OpCounter;
use adaptive_sampling::net::{
    frame, replay_answer, ErrorCode, NetClient, NetConfig, NetServer, Request, Response,
    ServeTarget, ShardSet, SolveConfig, Welcome, WireAnswer,
};
use adaptive_sampling::store::{DatasetView, LiveStore, StoreOptions};
use adaptive_sampling::util::rng::Rng;
use common::*;

fn net_chaos_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

static DIR_SERIAL: AtomicU64 = AtomicU64::new(0);

fn scratch_dir(tag: &str) -> PathBuf {
    let serial = DIR_SERIAL.fetch_add(1, Ordering::Relaxed);
    let name = format!("as_net_{tag}_{}_{serial}", std::process::id());
    std::env::temp_dir().join(name)
}

/// Exact-regime fixture: `batch_size >= d` makes every per-shard bandit
/// estimate exact, so the provable answer is the brute-force top-k.
const N: usize = 96;
const D: usize = 48;
const K: usize = 3;

fn solve_cfg() -> SolveConfig {
    SolveConfig { k: K, delta: 1e-3, batch_size: 64 }
}

/// Brute-force top-k with the merge's exact ordering: score descending
/// via `total_cmp`, arm id ascending on ties.
fn exact_topk(view: &dyn DatasetView, q: &[f32], k: usize) -> Vec<usize> {
    let mut scored: Vec<(f64, usize)> = (0..view.n_rows()).map(|i| (view.dot(i, q), i)).collect();
    scored.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.truncate(k);
    scored.into_iter().map(|(_, i)| i).collect()
}

fn test_queries(n_queries: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n_queries).map(|_| (0..D).map(|_| rng.f32() * 4.0 - 2.0).collect()).collect()
}

/// A server config sized for tests: prompt read-timeout polling so
/// shutdown drains fast, everything else at the tentpole defaults.
fn test_cfg() -> NetConfig {
    NetConfig {
        shards: 4,
        k: K,
        batch_size: 64,
        warm_coords: 16,
        read_timeout_ms: 500,
        drain_timeout_ms: 10_000,
        ..Default::default()
    }
}

fn replay_solve_cfg(w: &Welcome) -> SolveConfig {
    SolveConfig { k: w.k, delta: w.delta, batch_size: w.batch_size }
}

/// Replays every `(query, answer)` pair offline from the durable
/// directory alone and demands bit-equality on atoms and sample count.
fn assert_replays(
    dir: &Path,
    opts: &StoreOptions,
    shards: usize,
    scfg: &SolveConfig,
    answers: &[(Vec<f32>, WireAnswer)],
) {
    for (i, (q, a)) in answers.iter().enumerate() {
        let again =
            replay_answer(dir, opts, shards, scfg, a.version, a.seed, &a.warm_coords, q).unwrap();
        assert_eq!(
            (&again.top_atoms, again.samples),
            (&a.top_atoms, a.samples),
            "answer {i} (v{}) did not replay bit-exact",
            a.version
        );
    }
}

// ---------------------------------------------------------------------
// Acceptance (a): scatter-gather top-k over {1, 2, 4, 8} shards is
// bit-identical to single-shard serving — and, in the exact regime, to
// brute force — for the same seed and warm start.
// ---------------------------------------------------------------------
#[test]
fn scatter_gather_topk_is_shard_count_invariant() {
    let view: Arc<dyn DatasetView> = Arc::new(gaussian(N, D, 31));
    let scfg = solve_cfg();
    let warm: Vec<usize> = Rng::new(0x77).sample_without_replacement(D, 16);
    for (qi, q) in test_queries(6, 0x51).iter().enumerate() {
        let want = exact_topk(&*view, q, K);
        for shards in [1usize, 2, 4, 8] {
            let set = ShardSet::new(view.clone(), shards);
            let got = set.solve(q, 0xBEEF ^ qi as u64, &warm, &scfg, &OpCounter::new());
            assert!(!got.degraded);
            assert_eq!(got.shards_ok, shards);
            assert_eq!(
                got.top_atoms, want,
                "query {qi}: {shards}-shard answer drifted from brute force"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Acceptance proof over the wire: answers served via TCP across
// mid-stream wire ingest replay bit-exact offline — the recovered
// manifest, the answer's triple, and the Welcome's solver settings are
// a complete replay recipe.
// ---------------------------------------------------------------------
#[test]
fn tcp_answers_replay_bit_exact_across_wire_ingest() {
    let dir = scratch_dir("replay");
    let opts = StoreOptions::default();
    let live = Arc::new(LiveStore::open(D, opts.clone(), &dir).unwrap());
    live.commit_batch(&gaussian(N, D, 31)).unwrap();

    let server =
        NetServer::start(ServeTarget::Live(live.clone()), "127.0.0.1:0", test_cfg()).unwrap();
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, 5_000).unwrap();
    let welcome = client.hello("replay-test").unwrap();
    assert_eq!((welcome.rows as usize, welcome.d), (N, D));

    let queries = test_queries(8, 0x52);
    let mut answers: Vec<(Vec<f32>, WireAnswer)> = Vec::new();
    for (i, q) in queries.iter().enumerate() {
        if i == 4 {
            let extra = gaussian(8, D, 77);
            let rows: Vec<Vec<f32>> = (0..8).map(|r| extra.row(r).to_vec()).collect();
            let (version, total) = client.ingest(rows).unwrap();
            assert_eq!(version, 2, "wire ingest must commit version 2");
            assert_eq!(total as usize, N + 8);
        }
        let a = client.query_answer(i as u64, q).unwrap();
        assert!(!a.degraded);
        assert_eq!(a.version, if i < 4 { 1 } else { 2 }, "answers must pin the live version");
        answers.push((q.clone(), a));
    }
    drop(client);
    server.shutdown();

    assert_replays(&dir, &opts, welcome.shards, &replay_solve_cfg(&welcome), &answers);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Acceptance (b): an overload burst is shed with typed `overloaded`
// frames — no hang, no dropped connection — and every admitted query
// still replays bit-exact afterwards.
// ---------------------------------------------------------------------
#[test]
fn overload_burst_sheds_typed_and_admitted_queries_replay() {
    let _g = net_chaos_lock();
    let dir = scratch_dir("overload");
    let opts = StoreOptions::default();
    let live = Arc::new(LiveStore::open(D, opts.clone(), &dir).unwrap());
    live.commit_batch(&gaussian(N, D, 31)).unwrap();

    let cfg = NetConfig { shards: 2, max_inflight: 1, ..test_cfg() };
    let server = NetServer::start(ServeTarget::Live(live.clone()), "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();

    // Every scatter leg stalls 1.5s, so the single in-flight slot is
    // still held while the staggered burst arrives.
    let sched = Schedule::new(21).every("net.shard.rpc", FaultKind::Stall(1500), 1);
    let guard = ScheduleGuard::install(sched).unwrap();
    let queries = test_queries(4, 0x53);
    let handles: Vec<_> = queries
        .iter()
        .cloned()
        .enumerate()
        .map(|(i, q)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                if i > 0 {
                    std::thread::sleep(Duration::from_millis(250));
                }
                let mut c = NetClient::connect(&addr, 30_000)?;
                c.query(i as u64, &q)
            })
        })
        .collect();
    let mut answers: Vec<(Vec<f32>, WireAnswer)> = Vec::new();
    let mut shed = 0usize;
    for (h, q) in handles.into_iter().zip(&queries) {
        match h.join().expect("client thread must not panic").unwrap() {
            Response::Answer(a) => {
                assert!(!a.degraded, "a stall delays, it must not degrade");
                answers.push((q.clone(), a));
            }
            Response::Error { code: ErrorCode::Overloaded, .. } => shed += 1,
            other => panic!("expected an answer or a typed shed, got {other:?}"),
        }
    }
    drop(guard);
    server.shutdown();

    assert!(!answers.is_empty(), "the first query must be admitted");
    assert!(shed >= 1, "the burst must shed at least one query");
    assert_eq!(answers.len() + shed, queries.len(), "every query gets a typed outcome");
    assert_replays(&dir, &opts, 2, &solve_cfg(), &answers);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Acceptance (c): killing a shard mid-query yields a flagged partial
// result — `degraded`, `shards_ok == shards - 1` — with zero panics,
// and the server keeps serving clean answers afterwards. Losing every
// shard degrades to an empty answer, still typed, still no panic.
// ---------------------------------------------------------------------
#[test]
fn lost_shard_flags_partial_results_over_tcp() {
    let _g = net_chaos_lock();
    let live = Arc::new(LiveStore::new(D, StoreOptions::default()).unwrap());
    live.commit_batch(&gaussian(N, D, 31)).unwrap();
    let server = NetServer::start(ServeTarget::Live(live), "127.0.0.1:0", test_cfg()).unwrap();
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, 30_000).unwrap();
    let q = &test_queries(1, 0x54)[0];

    let sched = Schedule::new(22).one_shot("net.shard.rpc", FaultKind::Panic, 1);
    let guard = ScheduleGuard::install(sched).unwrap();
    let partial = client.query_answer(1, q).unwrap();
    drop(guard);
    assert!(partial.degraded, "a lost shard must flag the answer");
    assert_eq!((partial.shards, partial.shards_ok), (4, 3));
    assert_eq!(partial.top_atoms.len(), K, "3 shards still cover k={K}");

    let sched = Schedule::new(23).every("net.shard.rpc", FaultKind::Error, 1);
    let guard = ScheduleGuard::install(sched).unwrap();
    let empty = client.query_answer(2, q).unwrap();
    drop(guard);
    assert!(empty.degraded);
    assert_eq!(empty.shards_ok, 0, "every leg lost");
    assert!(empty.top_atoms.is_empty(), "no surviving shard, no fabricated answer");

    let clean = client.query_answer(3, q).unwrap();
    assert!(!clean.degraded, "the server must heal once the fault clears");
    assert_eq!(clean.shards_ok, 4);
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------
// Ladder rung 2: a zero-refill token bucket admits exactly the burst,
// then answers typed `quota` frames — per client, so one greedy client
// cannot starve another.
// ---------------------------------------------------------------------
#[test]
fn per_client_quota_bursts_then_denies_without_cross_talk() {
    let view: Arc<dyn DatasetView> = Arc::new(gaussian(N, D, 31));
    let cfg = NetConfig { quota_burst: 2.0, quota_per_sec: 0.0, ..test_cfg() };
    let server = NetServer::start(ServeTarget::Static(view), "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();
    let q = &test_queries(1, 0x55)[0];

    let mut greedy = NetClient::connect(&addr, 5_000).unwrap();
    greedy.hello("greedy").unwrap();
    for id in 0..2 {
        let a = greedy.query_answer(id, q).unwrap();
        assert!(!a.top_atoms.is_empty(), "the burst must be admitted");
    }
    match greedy.query(2, q).unwrap() {
        Response::Error { code: ErrorCode::Quota, .. } => {}
        other => panic!("an exhausted bucket must answer `quota`, got {other:?}"),
    }

    let mut modest = NetClient::connect(&addr, 5_000).unwrap();
    modest.hello("modest").unwrap();
    let a = modest.query_answer(0, q).unwrap();
    assert!(!a.top_atoms.is_empty(), "another client's bucket must be untouched");
    drop((greedy, modest));
    server.shutdown();
}

// ---------------------------------------------------------------------
// Graceful drain: shutdown stops the accept loop and the listener, so
// later connections are refused rather than silently queued.
// ---------------------------------------------------------------------
#[test]
fn shutdown_drains_and_stops_accepting() {
    let view: Arc<dyn DatasetView> = Arc::new(gaussian(N, D, 31));
    let server = NetServer::start(ServeTarget::Static(view), "127.0.0.1:0", test_cfg()).unwrap();
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, 5_000).unwrap();
    client.ping().unwrap();
    drop(client);
    server.shutdown();
    let refused = NetClient::connect(&addr, 1_000).and_then(|mut c| c.ping());
    assert!(refused.is_err(), "a drained server must not accept new connections");
}

// ---------------------------------------------------------------------
// Typed request errors: a static corpus refuses wire ingest, and a
// width-mismatched query is `bad_request` — the connection survives
// both.
// ---------------------------------------------------------------------
#[test]
fn static_ingest_and_bad_width_answer_bad_request() {
    let view: Arc<dyn DatasetView> = Arc::new(gaussian(N, D, 31));
    let server = NetServer::start(ServeTarget::Static(view), "127.0.0.1:0", test_cfg()).unwrap();
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, 5_000).unwrap();

    match client.roundtrip(&Request::Ingest { rows: vec![vec![1.0; D]] }).unwrap() {
        Response::Error { code: ErrorCode::BadRequest, msg } => {
            assert!(msg.contains("static"), "the error must say why: {msg}");
        }
        other => panic!("static ingest must be bad_request, got {other:?}"),
    }
    match client.query(0, &[1.0; 3]).unwrap() {
        Response::Error { code: ErrorCode::BadRequest, msg } => {
            assert!(msg.contains("width"), "the error must name the mismatch: {msg}");
        }
        other => panic!("a width mismatch must be bad_request, got {other:?}"),
    }
    client.ping().expect("typed request errors must not poison the connection");
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------
// Frame-codec discipline at the public API: every truncation offset of
// a valid frame is a typed `Truncated`, a flipped byte is `Checksum`,
// an oversized prefix is `Oversized` (before any allocation), garbage
// magic is `BadMagic` — never a panic.
// ---------------------------------------------------------------------
#[test]
fn frame_codec_rejects_torn_and_corrupt_input_typed() {
    let full = frame::encode("{\"type\": \"ping\"}");
    assert_eq!(&full[..4], &frame::MAGIC[..]);
    for cut in 0..full.len() {
        let mut r = std::io::Cursor::new(full[..cut].to_vec());
        match frame::read_frame(&mut r) {
            Err(frame::FrameError::Closed) if cut == 0 => {}
            Err(frame::FrameError::Truncated { at }) => {
                assert_eq!(at, cut, "the error must report where the stream tore")
            }
            other => panic!("cut at {cut}: want a typed tear, got {other:?}"),
        }
    }
    for flip in 0..full.len() {
        let mut bytes = full.clone();
        bytes[flip] ^= 0x40;
        let mut r = std::io::Cursor::new(bytes);
        assert!(frame::read_frame(&mut r).is_err(), "flipped byte {flip} must not pass");
    }
    let mut oversized = Vec::from(frame::MAGIC);
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());
    oversized.extend_from_slice(&0u64.to_le_bytes());
    let mut r = std::io::Cursor::new(oversized);
    assert!(matches!(
        frame::read_frame(&mut r),
        Err(frame::FrameError::Oversized { len: u32::MAX })
    ));
}

// ---------------------------------------------------------------------
// Malformed bytes on a live socket: bad magic and oversized prefixes
// get a typed `bad_frame` answer; every-offset torn frames just close;
// the server survives all of it and keeps serving.
// ---------------------------------------------------------------------
#[test]
fn malformed_wire_bytes_get_typed_errors_and_the_server_survives() {
    let view: Arc<dyn DatasetView> = Arc::new(gaussian(N, D, 31));
    let server = NetServer::start(ServeTarget::Static(view), "127.0.0.1:0", test_cfg()).unwrap();
    let addr = server.addr().to_string();

    let read_error_frame = |bytes: &[u8]| -> Response {
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        raw.write_all(bytes).unwrap();
        raw.flush().unwrap();
        let payload = frame::read_frame(&mut raw).expect("a typed error frame, not a hang");
        let json = adaptive_sampling::util::json::Json::parse(&payload).unwrap();
        Response::from_json(&json).unwrap()
    };

    let mut bad_magic = vec![b'X'; frame::HEADER_BYTES];
    bad_magic[4..8].copy_from_slice(&4u32.to_le_bytes());
    match read_error_frame(&bad_magic) {
        Response::Error { code: ErrorCode::BadFrame, .. } => {}
        other => panic!("bad magic must answer bad_frame, got {other:?}"),
    }

    let mut oversized = Vec::from(frame::MAGIC);
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());
    oversized.extend_from_slice(&0u64.to_le_bytes());
    match read_error_frame(&oversized) {
        Response::Error { code: ErrorCode::BadFrame, msg } => {
            assert!(msg.contains("exceeds cap"), "the error must name the cause: {msg}");
        }
        other => panic!("an oversized prefix must answer bad_frame, got {other:?}"),
    }

    // The durability discipline, on a socket: tear a valid frame at
    // every byte offset; each tear costs only that connection.
    let full = frame::encode(&Request::Ping.to_json().to_pretty_string());
    for cut in 0..full.len() {
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&full[..cut]).unwrap();
        raw.flush().unwrap();
        drop(raw);
    }

    let mut client = NetClient::connect(&addr, 5_000).unwrap();
    client.ping().expect("the server must survive every torn frame");
    drop(client);
    server.shutdown();
}
