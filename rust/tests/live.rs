//! Live data plane integration tests: snapshot isolation under
//! concurrent ingest, the warm-started `refresh` acceptance contract
//! over the fixture corpus (same answers as cold at < 50% of the cold
//! `OpCounter` cost), the ingest/query stress test with a serial-replay
//! oracle at thread counts {1, 2, 4, 8}, tombstone/remap fallbacks, and
//! the CI store-matrix entry point (`AS_TEST_STORE`).

mod common;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use adaptive_sampling::coordinator::{Backend, MipsServer, ServerConfig};
use adaptive_sampling::data::distance::Metric;
use adaptive_sampling::forest::split::{feature_ranges_view, make_edges};
use adaptive_sampling::forest::{
    refresh_split, solve_exact_cached, solve_exactly, solve_mab, Forest, ForestConfig,
    ForestKind, Impurity, Solver, SplitContext, TrainSet,
};
use adaptive_sampling::kmedoids::banditpam::{bandit_pam, bandit_pam_refresh, BanditPamConfig};
use adaptive_sampling::metrics::OpCounter;
use adaptive_sampling::mips::banditmips::{
    bandit_mips, bandit_mips_warm, BanditMipsConfig, SampleStrategy,
};
use adaptive_sampling::mips::refresh::{refresh as mips_refresh, solve_model};
use adaptive_sampling::mips::naive_mips;
use adaptive_sampling::store::{
    DatasetView, LiveSnapshot, LiveStore, StoreOptions, ViewPointSet,
};
use adaptive_sampling::util::rng::Rng;
use common::*;

fn live_opts(rows_per_chunk: usize) -> StoreOptions {
    StoreOptions { rows_per_chunk, ..Default::default() }
}

/// A root-node split context over the whole view, with equal-width edges
/// built from the view's (stats-backed) feature ranges.
fn root_ctx<'a>(
    x: &'a dyn DatasetView,
    y: &'a [f32],
    n_classes: usize,
    rows: &'a [usize],
    features: &'a [usize],
    counter: &'a OpCounter,
) -> SplitContext<'a> {
    SplitContext {
        ds: TrainSet { x, y, n_classes },
        rows,
        features,
        edges: make_edges(features, &feature_ranges_view(x), 10, false, &mut Rng::new(1)),
        impurity: Impurity::Gini,
        counter,
    }
}

/// A BanditMIPS config whose batch covers every coordinate in one round:
/// with permutation sampling the estimates are then *exact* at full
/// coverage, so cold answers are the true top-k deterministically — the
/// reference the warm refresh must reproduce.
fn exact_mips_cfg(d: usize, k: usize) -> BanditMipsConfig {
    BanditMipsConfig { k, batch_size: d.max(32), ..Default::default() }
}

// ---------------------------------------------------------------------
// Snapshot isolation
// ---------------------------------------------------------------------

/// Property: a concurrent reader pins version N or N+1 — never a blend.
/// Every committed batch carries its batch index in column 0, so any
/// torn read (rows of batch b visible without all of batches 0..b, or a
/// partial batch) is detected by a single column scan.
#[test]
fn concurrent_readers_never_observe_a_half_applied_batch() {
    const BATCH: usize = 25;
    const BATCHES: usize = 40;
    let live = Arc::new(LiveStore::new(4, live_opts(16)).unwrap());
    let done = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..4 {
        let live = live.clone();
        let done = done.clone();
        readers.push(std::thread::spawn(move || {
            let mut last_version = 0u64;
            let mut checks = 0usize;
            while !done.load(Ordering::Acquire) || checks == 0 {
                let snap = live.pin();
                let v = DatasetView::version(&*snap);
                assert!(v >= last_version, "pins must be monotone: {v} < {last_version}");
                last_version = v;
                let n = snap.n_rows();
                assert_eq!(
                    n,
                    v as usize * BATCH,
                    "version {v} must hold exactly {v} complete batches"
                );
                let rows: Vec<usize> = (0..n).collect();
                let mut col = vec![0f32; n];
                snap.read_col(0, &rows, &mut col);
                for (r, &marker) in col.iter().enumerate() {
                    assert_eq!(
                        marker,
                        (r / BATCH) as f32,
                        "row {r} of version {v} shows a blended batch"
                    );
                }
                checks += 1;
            }
            checks
        }));
    }
    for b in 0..BATCHES {
        let mut m = gaussian(BATCH, 4, 1_000 + b as u64);
        for i in 0..BATCH {
            m.row_mut(i)[0] = b as f32;
        }
        live.commit_batch(&m).unwrap();
        std::thread::yield_now();
    }
    done.store(true, Ordering::Release);
    for r in readers {
        let checks = r.join().unwrap();
        assert!(checks > 0, "reader never got to check anything");
    }
    assert_eq!(DatasetView::version(&*live.pin()), BATCHES as u64);
}

// ---------------------------------------------------------------------
// Warm-started refresh acceptance (the tentpole contract)
// ---------------------------------------------------------------------

/// For every fixture seed: the warm-started MIPS refresh after an append
/// returns the same top-k atoms as a cold solve on the same snapshot,
/// for under 50% of the cold solve's OpCounter samples.
#[test]
fn mips_refresh_matches_cold_at_under_half_cost_on_every_fixture() {
    for fx in refresh_corpus() {
        let d = fx.base.x.d;
        let live = LiveStore::new(d, live_opts(64)).unwrap();
        let snap_a = live.commit_batch(&fx.base.x).unwrap();
        let cfg = exact_mips_cfg(d, 3);
        let mut rq = Rng::new(fx.seed ^ 0x9E00);
        let qi = rq.below(fx.base.x.n);
        let q: Vec<f32> = fx.base.x.row(qi).iter().map(|&v| v * 1.25).collect();

        let c_prev = OpCounter::new();
        let (_, model) = solve_model(&*snap_a, &q, &cfg, &c_prev);

        let snap_b = live.commit_batch(&fx.append.x).unwrap();
        let c_cold = OpCounter::new();
        let (cold, _) = solve_model(&*snap_b, &q, &cfg, &c_cold);
        let c_warm = OpCounter::new();
        let (warm, model_b) = mips_refresh(&*snap_b, &q, &model, &cfg, &c_warm);

        assert_eq!(warm.atoms, cold.atoms, "{}: warm != cold", fx.name);
        assert!(
            c_warm.get() * 2 < c_cold.get(),
            "{}: warm {} is not < 50% of cold {}",
            fx.name,
            c_warm.get(),
            c_cold.get()
        );
        assert_eq!(model_b.n_rows, snap_b.n_rows());
        assert_eq!(model_b.version, DatasetView::version(&*snap_b));
    }
}

/// For every clusterable fixture seed: warm-started BanditPAM refresh
/// lands on the same medoids (and loss bits) as a cold solve on the
/// grown snapshot, for under 50% of the cold distance evaluations.
#[test]
fn kmedoids_refresh_matches_cold_at_under_half_cost_on_clusterable_fixtures() {
    for fx in refresh_corpus().into_iter().filter(|f| f.clusterable) {
        let d = fx.base.x.d;
        let live = LiveStore::new(d, live_opts(64)).unwrap();
        let snap_a = live.commit_batch(&fx.base.x).unwrap();
        let snap_b = live.commit_batch(&fx.append.x).unwrap();
        let mut cfg = BanditPamConfig::new(fx.k);
        cfg.km.seed = fx.seed;

        let ps_a = ViewPointSet::new(snap_a.clone(), Metric::L2);
        let prev = bandit_pam(&ps_a, &cfg);

        let ps_cold = ViewPointSet::new(snap_b.clone(), Metric::L2);
        let cold = bandit_pam(&ps_cold, &cfg);
        let ps_warm = ViewPointSet::new(snap_b.clone(), Metric::L2);
        let warm = bandit_pam_refresh(&ps_warm, &prev.medoids, &cfg);

        assert_eq!(warm.medoids, cold.medoids, "{}: medoids diverged", fx.name);
        assert_eq!(warm.loss.to_bits(), cold.loss.to_bits(), "{}: loss bits", fx.name);
        assert!(
            warm.dist_calls * 2 < cold.dist_calls,
            "{}: warm {} is not < 50% of cold {}",
            fx.name,
            warm.dist_calls,
            cold.dist_calls
        );
    }
}

/// For every fixture seed: the warm-started node-split refresh returns
/// the same (feature, threshold, impurity) as a cold exact solve on the
/// grown snapshot — bit for bit, classification histograms being
/// order-independent — for under 50% of the cold insertions (both the
/// exact scan's and MABSplit's).
#[test]
fn split_refresh_matches_cold_at_under_half_cost_on_every_fixture() {
    for fx in refresh_corpus() {
        let d = fx.base.x.d;
        let full = fx.full();
        let live = LiveStore::new(d, live_opts(64)).unwrap();
        let snap_a = live.commit_batch(&fx.base.x).unwrap();
        let snap_b = live.commit_batch(&fx.append.x).unwrap();
        let features: Vec<usize> = (0..d).collect();
        let rows_a: Vec<usize> = (0..fx.base.x.n).collect();
        let rows_b: Vec<usize> = (0..full.x.n).collect();
        let new_rows: Vec<usize> = (fx.base.x.n..full.x.n).collect();

        let c_prev = OpCounter::new();
        let ctx_a = root_ctx(&*snap_a, &full.y, full.n_classes, &rows_a, &features, &c_prev);
        let (_, mut cache) = solve_exact_cached(&ctx_a).unwrap();

        let c_exact = OpCounter::new();
        let ctx_b = root_ctx(&*snap_b, &full.y, full.n_classes, &rows_b, &features, &c_exact);
        let cold_exact = solve_exactly(&ctx_b).unwrap();
        let c_mab = OpCounter::new();
        let ctx_b2 = root_ctx(&*snap_b, &full.y, full.n_classes, &rows_b, &features, &c_mab);
        let cold_mab = solve_mab(&ctx_b2, 100, 0.01, fx.seed).unwrap();

        let c_warm = OpCounter::new();
        let ts_b = TrainSet { x: &*snap_b, y: &full.y, n_classes: full.n_classes };
        let warm = refresh_split(&mut cache, &ts_b, &rows_b, &new_rows, &c_warm).unwrap();

        assert_eq!(
            (warm.feature, warm.threshold.to_bits(), warm.child_impurity.to_bits()),
            (
                cold_exact.feature,
                cold_exact.threshold.to_bits(),
                cold_exact.child_impurity.to_bits()
            ),
            "{}: warm split != cold exact split",
            fx.name
        );
        // MABSplit is the chapter's cold solver: the warm split must be at
        // least as good, and cheaper than half its insertions too.
        assert!(
            warm.child_impurity <= cold_mab.child_impurity + 1e-9,
            "{}: warm impurity {} worse than cold MABSplit {}",
            fx.name,
            warm.child_impurity,
            cold_mab.child_impurity
        );
        assert!(
            c_warm.get() * 2 < c_exact.get(),
            "{}: warm {} not < 50% of exact cold {}",
            fx.name,
            c_warm.get(),
            c_exact.get()
        );
        assert!(
            c_warm.get() * 2 < c_mab.get(),
            "{}: warm {} not < 50% of MABSplit cold {}",
            fx.name,
            c_warm.get(),
            c_mab.get()
        );
    }
}

// ---------------------------------------------------------------------
// Ingest/query stress with a serial-replay oracle
// ---------------------------------------------------------------------

fn fingerprint_answer(atoms: &[usize], samples: u64) -> u64 {
    let as_f32: Vec<f32> = atoms.iter().map(|&a| a as f32).collect();
    fingerprint_bits(&as_f32) ^ samples.wrapping_mul(0x9E3779B97F4A7C15)
}

/// One ingest thread commits batches while N query threads hammer the
/// coordinator; every response names the (version, seed) it was served
/// with, and a serial replay of that exact interleaving — same snapshot,
/// same seed, one thread — must reproduce every answer and sample count
/// bit for bit.
#[test]
fn ingest_query_stress_is_bit_identical_to_serial_replay() {
    for &threads in &[1usize, 2, 4, 8] {
        stress_round(threads);
    }
}

fn stress_round(threads: usize) {
    const D: usize = 48;
    let live = Arc::new(LiveStore::new(D, live_opts(32)).unwrap());
    let snaps: Arc<Mutex<HashMap<u64, Arc<LiveSnapshot>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let s0 = live.commit_batch(&gaussian(80, D, 7)).unwrap();
    snaps.lock().unwrap().insert(DatasetView::version(&*s0), s0);

    let cfg = ServerConfig {
        workers: threads,
        max_batch: 4,
        batch_timeout_us: 200,
        validate_every: 0, // no PJRT in this test
        // default warm_coords stays on: responses carry the batch-shared
        // warm cache, so the replay reconstructs it exactly.
        ..Default::default()
    };
    let server = Arc::new(MipsServer::start(live.clone(), cfg.clone(), Backend::NativeBandit));

    // Ingest thread: 10 commits racing the queries.
    let ingest = {
        let live = live.clone();
        let snaps = snaps.clone();
        std::thread::spawn(move || {
            for b in 0..10u64 {
                let s = live.commit_batch(&gaussian(16, D, 100 + b)).unwrap();
                snaps.lock().unwrap().insert(DatasetView::version(&*s), s);
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    };

    // Query threads.
    let mut workers = Vec::new();
    for t in 0..threads {
        let server = server.clone();
        workers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x5717E55 ^ t as u64);
            let mut out = Vec::new();
            for i in 0..25 {
                let q: Vec<f32> = (0..D).map(|_| rng.f32() * 4.0 - 2.0).collect();
                let rx = server.submit(q.clone());
                let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
                out.push((format!("t{t}-q{i}"), q, resp));
            }
            out
        }));
    }
    let mut responses = Vec::new();
    for w in workers {
        responses.extend(w.join().unwrap());
    }
    ingest.join().unwrap();
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("server still referenced after joins"),
    }

    // Serial replay of the recorded interleaving.
    let mut live_trace = Trace::new();
    let mut replay_trace = Trace::new();
    let snaps = snaps.lock().unwrap();
    for (label, q, resp) in &responses {
        live_trace.record(label.clone(), fingerprint_answer(&resp.top_atoms, resp.samples));
        let snap = snaps
            .get(&resp.version)
            .unwrap_or_else(|| panic!("{label}: version {} not retained", resp.version));
        let mcfg = BanditMipsConfig {
            delta: cfg.delta,
            batch_size: 64,
            strategy: SampleStrategy::Uniform,
            sigma: None,
            k: cfg.k,
            seed: resp.seed,
            threads: 1,
        };
        let c = OpCounter::new();
        let again = bandit_mips_warm(&**snap, q, &mcfg, &c, &resp.warm_coords);
        replay_trace.record(label.clone(), fingerprint_answer(&again.atoms, again.samples));
        assert_eq!(
            (&again.atoms, again.samples),
            (&resp.top_atoms, resp.samples),
            "threads={threads} {label}: live vs serial replay diverged at v{}",
            resp.version
        );
    }
    assert_eq!(
        live_trace.first_divergence(&replay_trace),
        None,
        "threads={threads}: golden traces diverged"
    );
    assert_eq!(responses.len(), threads * 25);
}

// ---------------------------------------------------------------------
// Tombstones × refresh fallbacks
// ---------------------------------------------------------------------

/// Deleting a non-incumbent row remaps the standing model into the new
/// version and the warm refresh still matches cold; deleting an
/// incumbent kills the remap, forcing (correctly) a cold fallback.
#[test]
fn tombstoned_models_remap_or_fall_back_to_cold() {
    let d = 24;
    let base = gaussian(200, d, 53);
    let live = LiveStore::new(d, live_opts(32)).unwrap();
    let snap_a = live.commit_batch(&base).unwrap();
    let q: Vec<f32> = base.row(11).iter().map(|&v| v * 1.5).collect();
    let cfg = exact_mips_cfg(d, 2);
    let c = OpCounter::new();
    let (_, model) = solve_model(&*snap_a, &q, &cfg, &c);
    let incumbent_ids: Vec<u64> = model.top.iter().map(|&(r, _)| snap_a.stable_id(r)).collect();

    // Delete a row that is NOT an incumbent.
    let victim = (0..200u64).find(|id| !incumbent_ids.contains(id)).unwrap();
    let snap_b = live.delete_rows(&[victim]).unwrap();
    let remapped = model
        .remap(snap_b.n_rows(), |r| snap_b.locate(snap_a.stable_id(r)))
        .expect("incumbents survived");
    let c_cold = OpCounter::new();
    let (cold, _) = solve_model(&*snap_b, &q, &cfg, &c_cold);
    let c_warm = OpCounter::new();
    let (warm, _) = mips_refresh(&*snap_b, &q, &remapped, &cfg, &c_warm);
    assert_eq!(warm.atoms, cold.atoms, "remapped warm refresh must match cold");
    assert!(c_warm.get() < c_cold.get());

    // Delete the top incumbent: remap reports the loss, caller goes cold.
    let snap_c = live.delete_rows(&[incumbent_ids[0]]).unwrap();
    assert!(
        remapped
            .remap(snap_c.n_rows(), |r| snap_c.locate(snap_b.stable_id(r)))
            .is_none(),
        "losing an incumbent must invalidate the model"
    );
    let c2 = OpCounter::new();
    let (cold_c, _) = solve_model(&*snap_c, &q, &cfg, &c2);
    let truth = naive_mips(&*snap_c, &q, 2, &OpCounter::new());
    assert_eq!(cold_c.atoms, truth, "cold solve on tombstoned snapshot is exact");
}

// ---------------------------------------------------------------------
// CI store-matrix entry point
// ---------------------------------------------------------------------

/// The body CI sweeps with `AS_TEST_STORE` × `AS_THREADS`: the solver
/// suite runs on the env-selected substrate (dense matrix by default,
/// columnar f32, or quantized+spilled i8) and stays correct on all of
/// them — exact answers where the codec is lossless or the solve covers
/// every coordinate, quality thresholds where quantization blurs bits.
#[test]
fn solver_suite_runs_on_env_selected_substrate() {
    let opts = store_options_from_env();
    let fx = refresh_corpus()
        .into_iter()
        .find(|f| f.name == "small-clusterable")
        .unwrap();
    let full = fx.full();
    let view = materialize(&full.x, &opts);

    // BanditMIPS vs naive over the SAME view: both read the same decoded
    // values, and full-coverage permutation estimates are exact, so the
    // answers agree even under a lossy codec.
    let q: Vec<f32> = full.x.row(5).iter().map(|&v| v * 1.2).collect();
    let cfg = exact_mips_cfg(full.x.d, 2);
    let c = OpCounter::new();
    let ans = bandit_mips(&*view, &q, &cfg, &c);
    let truth = naive_mips(&*view, &q, 2, &OpCounter::new());
    assert_eq!(ans.atoms, truth, "bandit vs naive on the same substrate");

    // MABSplit forest trains on the substrate.
    let ts = TrainSet { x: &*view, y: &full.y, n_classes: full.n_classes };
    let cf = OpCounter::new();
    let mut fcfg = ForestConfig::new(ForestKind::RandomForest, Solver::mab());
    fcfg.n_trees = 3;
    let forest = Forest::fit_view(&ts, &fcfg, &cf);
    let acc = forest.accuracy_view(&ts);
    assert!(acc > 0.8, "substrate forest accuracy {acc}");

    // BanditPAM clusters through the substrate.
    let ps = ViewPointSet::new(view, Metric::L2);
    let km = bandit_pam(&ps, &BanditPamConfig::new(fx.k));
    assert_eq!(km.medoids.len(), fx.k);
    assert!(km.loss.is_finite());
}
