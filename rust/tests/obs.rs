//! Observability contract tests.
//!
//! The load-bearing claim of `src/obs` is the **no-perturbation
//! contract**: enabling sampling telemetry and tracing changes no
//! answer digest and no gated op count, at any thread count. This suite
//! pins that bit-exactly across the smoke-tier scenario registry
//! (threads {1, 8} included), plus the serialization and ring-buffer
//! invariants the `repro trace` / `repro metrics` CLIs rely on.
//!
//! The obs enabled flag and the trace ring registry are process-global,
//! so every test that toggles them serializes on [`obs_lock`] and
//! drains before it starts. Tests here can therefore assert on whole
//! drained documents — unlike the unit tests inside `src/obs`, which
//! share their process with the rest of the crate's test threads.

use adaptive_sampling::harness::{scenarios_for, Tier};
use adaptive_sampling::obs::{self, trace, LogHistogram, MetricsRegistry, MetricsSnapshot};
use adaptive_sampling::util::json::Json;
use adaptive_sampling::util::rng::Rng;
use std::sync::Mutex;

fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------
// The acceptance criterion: instrumentation on vs off is invisible to
// the deterministic cost model. Every smoke scenario (which spans the
// three solver families, the store backends, cold + refresh paths, and
// threads {1, 8}) must produce a bit-identical CostRecord — same
// counters, same answer digest — with tracing enabled.
// ---------------------------------------------------------------------
#[test]
fn instrumentation_changes_no_digest_or_op_count() {
    let _g = obs_lock();
    obs::set_enabled(false);
    drop(obs::drain());
    let scenarios = scenarios_for(Tier::Smoke);
    assert!(scenarios.iter().any(|s| s.name().ends_with("/t1")), "smoke tier lost its t1 runs");
    assert!(scenarios.iter().any(|s| s.name().ends_with("/t8")), "smoke tier lost its t8 runs");
    let off: Vec<_> = scenarios.iter().map(|s| s.run()).collect();
    obs::set_enabled(true);
    let on: Vec<_> = scenarios.iter().map(|s| s.run()).collect();
    obs::set_enabled(false);
    drop(obs::drain());
    for (a, b) in off.iter().zip(&on) {
        assert_eq!(a, b, "{}: enabling observability perturbed the cost model", a.scenario);
    }
}

// ---------------------------------------------------------------------
// A traced BanditMIPS run drains to parseable JSON whose spans nest
// strictly and whose per-span arms-alive series are monotone
// non-increasing — the same checks `repro trace` enforces in CI.
// ---------------------------------------------------------------------
#[test]
fn traced_banditmips_run_emits_monotone_round_telemetry() {
    let _g = obs_lock();
    let scenario = adaptive_sampling::harness::registry()
        .into_iter()
        .find(|s| s.name() == "banditmips/cold/sm/matrix/t1")
        .expect("registered scenario");
    obs::set_enabled(false);
    drop(obs::drain());
    obs::set_enabled(true);
    let record = scenario.run();
    obs::set_enabled(false);
    let text = obs::drain().to_pretty_string();
    let doc = Json::parse(&text).expect("trace parses back from its serialized form");
    let stats = obs::validate(&doc).expect("trace validates");
    assert_eq!(stats.dropped, 0, "smoke-sized run must fit the ring");
    assert!(stats.spans >= 2, "expected solver spans from warm-up + measured passes: {stats:?}");
    assert!(stats.rounds > 0, "bandit engine emitted no round telemetry");
    assert!(record.counters.get("ops").unwrap_or(0) > 0, "solver did no work");
    let series = obs::arms_alive_series(&doc);
    assert!(!series.is_empty());
    for (span, alives) in &series {
        assert!(
            alives.windows(2).all(|w| w[0] >= w[1]),
            "span {span}: arms-alive series is not monotone non-increasing: {alives:?}"
        );
    }
}

// ---------------------------------------------------------------------
// MetricsSnapshot serializes byte-stably through the canonical JSON
// writer: serialize ∘ parse ∘ serialize is the identity on bytes, the
// same discipline as the perf-gate record files.
// ---------------------------------------------------------------------
#[test]
fn metrics_snapshot_round_trips_byte_stably() {
    let r = MetricsRegistry::default();
    r.counter("serve.queries").add(12_345);
    r.counter("serve.batches").add(99);
    r.gauge("live.version").set(7);
    r.gauge("store.cache_resident_bytes").set(1 << 20);
    let h = r.histogram("serve.latency_us");
    let mut rng = Rng::new(42);
    for _ in 0..500 {
        h.record(rng.below(2_000_000) as u64);
    }
    let snap = r.snapshot();
    let text = snap.to_json().to_pretty_string();
    let back = MetricsSnapshot::from_json(&Json::parse(&text).expect("snapshot parses"))
        .expect("snapshot deserializes");
    assert_eq!(back, snap);
    assert_eq!(
        back.to_json().to_pretty_string(),
        text,
        "serialize ∘ parse must be the identity on bytes"
    );
    let rendered = snap.render();
    assert!(rendered.contains("serve.latency_us"));
    assert!(rendered.contains("µs"));
}

// ---------------------------------------------------------------------
// Histogram merge is associative (and order-insensitive), so per-shard
// histograms can aggregate in any grouping; quantiles are monotone
// non-decreasing in q by construction.
// ---------------------------------------------------------------------
#[test]
fn histogram_merge_is_associative_and_quantiles_monotone() {
    let mk = |seed: u64, n: usize| {
        let mut rng = Rng::new(seed);
        let mut h = LogHistogram::new();
        for _ in 0..n {
            h.record(rng.below(1_000_000_000) as u64);
        }
        h
    };
    let (a, b, c) = (mk(1, 400), mk(2, 250), mk(3, 777));
    let mut ab_c = a.clone();
    ab_c.merge(&b);
    ab_c.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut a_bc = a.clone();
    a_bc.merge(&bc);
    assert_eq!(ab_c, a_bc, "merge must be associative");
    let mut c_b_a = c.clone();
    c_b_a.merge(&b);
    c_b_a.merge(&a);
    assert_eq!(ab_c, c_b_a, "merge must be order-insensitive");
    assert_eq!(ab_c.count(), 400 + 250 + 777);
    let mut prev = 0u64;
    for i in 0..=100 {
        let q = i as f64 / 100.0;
        let v = ab_c.quantile(q);
        assert!(v >= prev, "quantile({q}) = {v} < quantile of smaller q ({prev})");
        prev = v;
    }
    assert_eq!(ab_c.quantile(1.0), ab_c.max());
}

// ---------------------------------------------------------------------
// Ring overflow drops the *oldest* events, counts the drops, and the
// drained document still validates (with nesting checks relaxed for
// the thread whose prefix was lost).
// ---------------------------------------------------------------------
#[test]
fn ring_overflow_keeps_newest_events_and_counts_drops() {
    let _g = obs_lock();
    obs::set_enabled(false);
    drop(obs::drain());
    obs::set_enabled(true);
    let extra = 250usize;
    let total = trace::RING_CAPACITY + extra;
    for i in 0..total {
        obs::emit_round(obs::RoundTrace {
            round: i,
            arms_alive: 1,
            pulls: 1,
            n_used: 1,
            min_ci: 0.0,
            mean_ci: 0.0,
        });
    }
    obs::set_enabled(false);
    let doc = obs::drain();
    let threads = doc.get("threads").and_then(Json::as_arr).expect("threads array");
    assert_eq!(threads.len(), 1, "only this thread emitted since the last drain");
    let t = &threads[0];
    assert_eq!(t.get("dropped").and_then(Json::as_u64), Some(extra as u64));
    let events = t.get("events").and_then(Json::as_arr).expect("events array");
    assert_eq!(events.len(), trace::RING_CAPACITY);
    assert_eq!(events[0].get("round").and_then(Json::as_u64), Some(extra as u64));
    assert_eq!(
        events[events.len() - 1].get("round").and_then(Json::as_u64),
        Some(total as u64 - 1)
    );
    let stats = obs::validate(&doc).expect("dropped-prefix trace still validates");
    assert_eq!(stats.dropped, extra as u64);
    assert_eq!(stats.rounds, trace::RING_CAPACITY);
}
