//! Shared helpers for the integration test binaries.
//!
//! The heavy lifting lives in the library's `util::testkit` (fixture
//! corpus, fingerprints, env-selected store substrate) so unit tests,
//! benches, and examples share it too; this module only adds the few
//! glue helpers that integration tests need and re-exports the kit under
//! one roof (`mod common;` + `use common::*`).

#![allow(dead_code)] // each test binary uses a different subset

pub use adaptive_sampling::util::testkit::*;

use adaptive_sampling::data::{LabeledDataset, Matrix};

/// Stack labeled datasets vertically (shared width and class count).
pub fn stack_labeled(parts: &[&LabeledDataset]) -> LabeledDataset {
    let xs: Vec<&Matrix> = parts.iter().map(|p| &p.x).collect();
    let mut y = Vec::new();
    for p in parts {
        assert_eq!(p.n_classes, parts[0].n_classes);
        y.extend_from_slice(&p.y);
    }
    LabeledDataset { x: stack(&xs), y, n_classes: parts[0].n_classes }
}
